"""Pallas FastSparseMoE kernels vs the pure-jnp / numpy oracles.

The core correctness signal of the L1 layer: Algorithm 1 stages 2-5 must
match the paper-transcript references entry-by-entry (integer plumbing)
and numerically (expert compute + reduction + gradients).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fast_moe, ref


def make_routing(rng, t, n, k):
    """Random distinct top-k expert ids + weights for t tokens."""
    idx = np.stack([rng.permutation(n)[:k] for _ in range(t)]).astype(np.int32)
    w = rng.random((t, k)).astype(np.float32)
    w /= w.sum(axis=1, keepdims=True)
    return w, idx


CASES = [
    # (T, N, K, EP, tbs)
    (16, 8, 2, 1, 8),
    (16, 8, 2, 2, 4),
    (32, 16, 4, 4, 8),
    (24, 6, 2, 2, 4),
    (8, 4, 2, 2, 2),   # the Figure 5 regime: tiny T, 2 ranks
]


@pytest.mark.parametrize("t,n,k,ep,tbs", CASES)
def test_token_counts_matches_ref(t, n, k, ep, tbs):
    rng = np.random.default_rng(42 + t + n)
    _, idx = make_routing(rng, t, n, k)
    nr = n // ep
    for r in range(ep):
        n_start, n_end = r * nr, (r + 1) * nr - 1
        want = ref.ref_token_counts(idx, n_start, n_end, tbs)
        partial, pcum, cum_token, expert_counts, cum_expert = [
            np.asarray(x) for x in fast_moe.token_counts(
                jnp.asarray(idx), n_start, nr, tbs)]
        np.testing.assert_array_equal(partial, want["partial_token_counts"])
        np.testing.assert_array_equal(pcum, want["partial_cum_token_counts"])
        np.testing.assert_array_equal(cum_token, want["cum_token_counts"])
        np.testing.assert_array_equal(expert_counts, want["expert_counts"])
        np.testing.assert_array_equal(cum_expert, want["cum_expert_counts"])


@pytest.mark.parametrize("t,n,k,ep,tbs", CASES)
def test_index_generation_matches_ref(t, n, k, ep, tbs):
    rng = np.random.default_rng(7 + t * n)
    _, idx = make_routing(rng, t, n, k)
    nr = n // ep
    for r in range(ep):
        n_start, n_end = r * nr, (r + 1) * nr - 1
        want = ref.ref_index_generation(idx, n_start, n_end, tbs)
        meta = jax.tree.map(np.asarray, fast_moe.routing_metadata(
            jnp.asarray(idx), n_start, nr, tbs))
        rt = int(want["rt"])
        np.testing.assert_array_equal(
            meta["input_indices"][:rt], want["input_indices"])
        np.testing.assert_array_equal(
            meta["output_indices"][:rt], want["output_indices"])
        np.testing.assert_array_equal(
            meta["selected_expert_indices"][:rt],
            want["selected_expert_indices"])


def test_index_generation_figure5():
    """The paper's Figure 5 example: T=4, N=4, K=2, EP=2.

    Routing: T0->{E0,E3}, T1->{E1,E2}, T2->{E0,E1}, T3->{E2,E3}
    (a concrete assignment consistent with the figure). Rank 0 owns E0,E1;
    rank 1 owns E2,E3.
    """
    idx = np.array([[0, 3], [1, 2], [0, 1], [2, 3]], dtype=np.int32)
    # rank 0: local entries E0:{T0,T2} E1:{T1,T2}
    m0 = jax.tree.map(np.asarray,
                      fast_moe.routing_metadata(jnp.asarray(idx), 0, 2, 2))
    rt0 = int(m0["cum_token_counts"][-1])
    assert rt0 == 4
    np.testing.assert_array_equal(m0["input_indices"][:4], [0, 2, 1, 2])
    # rank 1: E2:{T1,T3} E3:{T0,T3}
    m1 = jax.tree.map(np.asarray,
                      fast_moe.routing_metadata(jnp.asarray(idx), 2, 2, 2))
    np.testing.assert_array_equal(m1["input_indices"][:4], [1, 3, 0, 3])


@pytest.mark.parametrize("t,n,k,ep,tbs", CASES)
def test_fast_moe_partial_matches_naive(t, n, k, ep, tbs):
    """End-to-end stages 2-5 vs the HF-style naive loop, per EP rank, and
    the sum over ranks vs the single-rank full computation."""
    rng = np.random.default_rng(1234 + t)
    h, i_dim = 16, 8
    x = rng.standard_normal((t, h)).astype(np.float32)
    w, idx = make_routing(rng, t, n, k)
    gate = 0.3 * rng.standard_normal((n, h, i_dim)).astype(np.float32)
    up = 0.3 * rng.standard_normal((n, h, i_dim)).astype(np.float32)
    down = 0.3 * rng.standard_normal((n, i_dim, h)).astype(np.float32)

    nr = n // ep
    total = np.zeros((t, h), np.float32)
    for r in range(ep):
        n_start = r * nr
        got = fast_moe.fast_sparse_moe_partial(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(idx),
            jnp.asarray(gate[n_start:n_start + nr]),
            jnp.asarray(up[n_start:n_start + nr]),
            jnp.asarray(down[n_start:n_start + nr]),
            n_start, tbs=tbs, tile=4)
        want = ref.naive_sparse_moe(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(idx),
            jnp.asarray(gate[n_start:n_start + nr]),
            jnp.asarray(up[n_start:n_start + nr]),
            jnp.asarray(down[n_start:n_start + nr]), n_start)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)
        total += np.asarray(got)
    # partial sums across EP ranks == full single-rank MoE
    full = ref.naive_sparse_moe(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(idx),
        jnp.asarray(gate), jnp.asarray(up), jnp.asarray(down), 0)
    np.testing.assert_allclose(total, np.asarray(full), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("t,n,k,ep,tbs", CASES[:3])
def test_fast_moe_gradients_match_naive(t, n, k, ep, tbs):
    """Gradients through stages 4-5 (custom VJPs incl. the paper's stage-5
    backward kernel) vs jax autodiff of the naive loop."""
    rng = np.random.default_rng(77 + t)
    h, i_dim = 8, 4
    x = rng.standard_normal((t, h)).astype(np.float32)
    w, idx = make_routing(rng, t, n, k)
    gate = 0.4 * rng.standard_normal((n, h, i_dim)).astype(np.float32)
    up = 0.4 * rng.standard_normal((n, h, i_dim)).astype(np.float32)
    down = 0.4 * rng.standard_normal((n, i_dim, h)).astype(np.float32)
    dy = rng.standard_normal((t, h)).astype(np.float32)

    nr = n // ep
    r = ep - 1  # test the last rank (offset indexing)
    n_start = r * nr
    args = (jnp.asarray(x), jnp.asarray(w),
            jnp.asarray(gate[n_start:n_start + nr]),
            jnp.asarray(up[n_start:n_start + nr]),
            jnp.asarray(down[n_start:n_start + nr]))

    def loss_fast(x_, w_, g_, u_, d_):
        out = fast_moe.fast_sparse_moe_partial(
            x_, w_, jnp.asarray(idx), g_, u_, d_, n_start, tbs=tbs, tile=4)
        return jnp.sum(out * jnp.asarray(dy))

    def loss_naive(x_, w_, g_, u_, d_):
        out = ref.naive_sparse_moe(x_, w_, jnp.asarray(idx), g_, u_, d_,
                                   n_start)
        return jnp.sum(out * jnp.asarray(dy))

    gf = jax.grad(loss_fast, argnums=(0, 1, 2, 3, 4))(*args)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2, 3, 4))(*args)
    for a, b, name in zip(gf, gn, ["dx", "dw", "dgate", "dup", "ddown"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5, err_msg=name)


@settings(max_examples=15, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    n_log=st.integers(1, 4),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_hypothesis_metadata_invariants(t_blocks, n_log, k, seed, data):
    """Property sweep over shapes: stage 2-3 invariants hold for any routing.

    - sum(token_counts) == RT == cum_token_counts[-1]
    - every valid input_indices entry is a token id in [0, T)
    - expert segments partition [0, RT)
    - output_indices is a permutation of [0, RT)
    """
    tbs = data.draw(st.sampled_from([2, 4, 8]))
    t = t_blocks * tbs
    n = 2 ** n_log
    k = min(k, n)
    ep = data.draw(st.sampled_from([d for d in (1, 2, 4) if n % d == 0]))
    rng = np.random.default_rng(seed)
    _, idx = make_routing(rng, t, n, k)
    nr = n // ep
    r = data.draw(st.integers(0, ep - 1))
    meta = jax.tree.map(np.asarray, fast_moe.routing_metadata(
        jnp.asarray(idx), r * nr, nr, tbs))
    cum = meta["cum_token_counts"]
    rt = int(cum[-1])
    assert rt == int(meta["expert_counts"].sum())
    assert rt <= t * k
    ii = meta["input_indices"][:rt]
    assert ((ii >= 0) & (ii < t)).all()
    oi = np.sort(meta["output_indices"][:rt])
    np.testing.assert_array_equal(oi, np.arange(rt))
    # each token appears exactly (#local chosen experts) times
    want_per_token = ((idx >= r * nr) & (idx < (r + 1) * nr)).sum(axis=1)
    got_per_token = np.bincount(ii, minlength=t)
    np.testing.assert_array_equal(got_per_token, want_per_token)
