//! Table 3 (EPSO column): EP-aware sharded optimizer vs standard sharded
//! optimizer — measured optimizer-component time in the real multi-rank
//! runtime, plus the closed-form projection at paper scale (EP=12), which
//! reproduces the paper's 1.36 / 1.23 / 1.07 almost exactly.

use optimus::cluster::epso_optimizer_speedup;
use optimus::config::models::{MULA_100B, MULA_20B, MULA_220B};
use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec};
use optimus::data::{corpus, preprocess};
use optimus::optim::ShardingMode;
use optimus::util::bench::Report;

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let data_dir = std::env::temp_dir().join("optimus-epso-bench");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 4, 32), 64, 7, &data_dir, 512)?;
    }

    let mut rep = Report::new(
        "Table 3 — EPSO vs SO (measured, mula-tiny, DP=2 EP=2, 12 steps)",
        &["mode", "opt state bytes/rank", "optimizer secs", "speedup"],
    );
    let mut run = |mode: ShardingMode| -> optimus::Result<(usize, f64)> {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(2, 2, 1)
            .steps(8)
            .sharding(mode)
            .build()?;
        let r = coordinator::train(&m, &spec)?;
        Ok((r.opt_state_bytes, r.optimizer_update_secs))
    };
    let (so_bytes, so_secs) = run(ShardingMode::So)?;
    let (ep_bytes, ep_secs) = run(ShardingMode::Epso)?;
    rep.row(&["SO".into(), so_bytes.to_string(), format!("{so_secs:.4}"), "1.00x".into()]);
    rep.row(&[
        "EPSO".into(),
        ep_bytes.to_string(),
        format!("{ep_secs:.4}"),
        format!("{:.2}x", so_secs / ep_secs.max(1e-9)),
    ]);
    rep.print();
    rep.write_csv("table3_epso").ok();

    let mut proj = Report::new(
        "Table 3 — EPSO optimizer-component projection at paper scale (EP=12)",
        &["model", "paper", "modeled"],
    );
    for (spec, paper) in [(&MULA_20B, 1.36), (&MULA_100B, 1.23), (&MULA_220B, 1.07)] {
        proj.row(&[
            spec.name.into(),
            format!("{paper:.2}x"),
            format!("{:.2}x", epso_optimizer_speedup(spec, 12)),
        ]);
    }
    proj.print();
    proj.write_csv("table3_epso_projection").ok();
    Ok(())
}
