//! Data pipeline (paper §4 "Data preprocessing"):
//! tokenize → shuffle → shard, then mmap'd lazy loading so every DP rank
//! reads contiguous memory with "bare minimal overhead".
//!
//! - [`tokenizer`] — byte-level tokenizer (+EOS), document framing
//! - [`corpus`]    — deterministic synthetic corpus generator (the
//!   OLMoE-Mix substitution; see DESIGN.md §1)
//! - [`preprocess`] — offline pipeline producing `.oshard` files
//! - [`dataset`]   — mmap shard reader + deterministic global batch plan

pub mod corpus;
pub mod dataset;
pub mod preprocess;
pub mod tokenizer;

pub use dataset::{BatchPlan, Dataset};
pub use preprocess::{preprocess, PreprocessStats};
pub use tokenizer::Tokenizer;
