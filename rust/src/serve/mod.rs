//! `optimus serve`: expert-parallel inference on the training mesh.
//!
//! The serving engine loads any committed training checkpoint through the
//! topology-elastic reshard path ([`crate::ckpt::ResumeState`]) and
//! re-slices it onto a *serving* placement — ep-only or dp×ep, validated
//! by [`ParallelismPlan::validate_serve`] — then runs expert-parallel
//! autoregressive greedy decode with:
//!
//! * a **continuous-batching scheduler** ([`scheduler`]) that admits new
//!   requests and evicts finished ones at every decode step, per lane
//!   (= rank), with a static-batching baseline mode for comparison;
//! * a **paged KV cache** ([`kv_cache`]) of `Arc`-backed tensor pages
//!   with free-list reuse and per-request page tables, whose exhaustion
//!   backpressures admission instead of aborting;
//! * a seeded **open-loop traffic generator** ([`traffic`]) whose
//!   workload is a pure function of its seed.
//!
//! Startup failures use three stable, `ft::classify`-friendly strings:
//! `serve startup failed [plan]` (bad serve configuration), `[kv-oom]`
//! (a pool that cannot host even one worst-case request), `[ckpt]` (no
//! loadable checkpoint). Checkpoint *mismatches* keep their training-side
//! strings (`checkpoint resume failed [model]`/`[param-count]`/`[dtype]`)
//! — a bf16 checkpoint offered to the f32 decode engine fails exactly
//! like a bf16 checkpoint offered to an f32 training plan.
//!
//! Report: per-request completions (deterministic — greedy decode makes
//! them a pure function of checkpoint + prompt), p50/p99 TTFT and
//! per-token-latency histograms ([`crate::metrics::Histogram`]),
//! tokens/sec, and KV-page accounting (`kv_pages_leaked` must be 0 —
//! CI's serve-smoke job and the leak test pin it).

mod engine;
mod kv_cache;
mod scheduler;
mod traffic;

pub use kv_cache::{KvPool, PageTable};
pub use scheduler::{BatchMode, Completion};
pub use traffic::{Request, TrafficConfig};

use crate::ckpt::{ResumeState, SavedCheckpoint};
use crate::comm::{Mesh, Topology};
use crate::config::{Manifest, ModelManifest};
use crate::coordinator::ParallelismPlan;
use crate::ft::checks;
use crate::metrics::Histogram;
use crate::runtime::{Engine, Tensor};
use crate::Result;
use engine::{Decoder, EpDecoder, FusedDecoder};
use scheduler::LaneReport;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Everything one serving run needs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    /// checkpoint root (a training run's `--ckpt-dir`)
    pub ckpt_dir: PathBuf,
    /// serving placement: ep-only or dp×ep, pp must be 1
    pub topo: Topology,
    pub mode: BatchMode,
    /// KV pages per lane
    pub kv_pages: usize,
    /// tokens per KV page
    pub kv_page_size: usize,
    /// PJRT executor pool size; 0 → one per rank
    pub engine_pool: usize,
    pub traffic: TrafficConfig,
}

impl ServeConfig {
    pub fn new(model: &str, ckpt_dir: &Path) -> ServeConfig {
        ServeConfig {
            model: model.to_string(),
            ckpt_dir: ckpt_dir.to_path_buf(),
            topo: Topology::dp_only(1),
            mode: BatchMode::Continuous,
            kv_pages: 16,
            kv_page_size: 8,
            engine_pool: 0,
            traffic: TrafficConfig::default(),
        }
    }
}

/// Aggregated results of a bounded serving run.
#[derive(Default)]
pub struct ServeReport {
    /// requests the traffic generator offered
    pub submitted: usize,
    /// finished requests, sorted by id; bounded runs are complete iff
    /// `completions.len() == submitted`
    pub completions: Vec<Completion>,
    /// time-to-first-token distribution (arrival → first decoded token),
    /// merged over lanes
    pub ttft: Histogram,
    /// per-token decode latency distribution, merged over lanes
    pub per_token: Histogram,
    pub tokens_generated: u64,
    /// fixed-shape decode steps executed (summed over lanes) — the
    /// deterministic cost measure the batching comparison gates on
    pub decode_steps: u64,
    pub wall_secs: f64,
    pub kv_pages_total: usize,
    /// pages still held after every lane drained — must be 0
    pub kv_pages_leaked: usize,
    /// peak simultaneous page occupancy across lanes
    pub kv_pages_peak: usize,
    /// training step the served checkpoint was written at
    pub resumed_step: usize,
}

impl ServeReport {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs == 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_secs
    }
}

/// Load + validate + reassemble the full parameter vector from the newest
/// loadable checkpoint under `dir`. Corrupt/uncommitted slots fall
/// through to older ones (the trainer's resume convention); a slot that
/// *loads* but mismatches the serving run (wrong model, wrong count, bf16
/// params) fails hard with the stable `checkpoint resume failed [...]`
/// strings. Returns `(params, step)`.
pub fn load_params(mm: &ModelManifest, dir: &Path) -> Result<(Vec<f32>, usize)> {
    let mut last_err: Option<anyhow::Error> = None;
    for saved in SavedCheckpoint::load_all(dir) {
        match ResumeState::open(&saved) {
            Ok(rs) => {
                rs.validate(&mm.name, mm.param_count)?;
                // the decode engine computes in f32; a bf16 checkpoint is
                // rejected the same way an f32 training plan rejects it
                rs.validate_dtype("f32")?;
                let params = rs.assemble_params(mm.param_count)?;
                return Ok((params, rs.step()));
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(checks::err(
        checks::SERVE,
        "ckpt",
        match last_err {
            Some(e) => format!("no loadable checkpoint under {}: {e:#}", dir.display()),
            None => format!("no committed checkpoint under {}", dir.display()),
        },
    ))
}

/// Serve-config preflight: everything that must hold before any thread
/// spawns, with the stable `serve startup failed [plan]` / `[kv-oom]`
/// strings. The placement itself is checked by
/// [`ParallelismPlan::validate_serve`] first.
fn validate_config(cfg: &ServeConfig, mm: &ModelManifest) -> Result<()> {
    let fail = |msg: String| Err(checks::err(checks::SERVE, "plan", msg));
    let t = &cfg.traffic;
    if t.requests == 0 {
        return fail("traffic offers zero requests; nothing to serve".to_string());
    }
    if t.queue_depth == 0 {
        return fail("queue depth 0 would deadlock admission; use >= 1".to_string());
    }
    if t.prompt_len.0 == 0 || t.prompt_len.0 > t.prompt_len.1 {
        return fail(format!(
            "prompt length range [{}, {}] must be non-empty and start at >= 1",
            t.prompt_len.0, t.prompt_len.1
        ));
    }
    if t.gen_len.0 == 0 || t.gen_len.0 > t.gen_len.1 {
        return fail(format!(
            "generation length range [{}, {}] must be non-empty and start at >= 1",
            t.gen_len.0, t.gen_len.1
        ));
    }
    let window = t.prompt_len.1 + t.gen_len.1;
    if window > mm.hyper.seq {
        return fail(format!(
            "worst-case request window {} ({} prompt + {} generated) exceeds the \
             fixed {}-token artifact window of {}",
            window,
            t.prompt_len.1,
            t.gen_len.1,
            mm.hyper.seq,
            mm.name
        ));
    }
    if cfg.kv_pages == 0 || cfg.kv_page_size == 0 {
        return fail(format!(
            "kv pool geometry {}x{} must be non-zero",
            cfg.kv_pages, cfg.kv_page_size
        ));
    }
    // a single worst-case request must fit a lane's pool, or its
    // admission would head-of-line-block the lane forever
    let need = window.div_ceil(cfg.kv_page_size);
    if need > cfg.kv_pages {
        return Err(checks::err(
            checks::SERVE,
            "kv-oom",
            format!(
                "a worst-case request needs {need} pages ({window} tokens at \
                 {} tokens/page) but each lane's pool holds only {} — grow \
                 --kv-pages or shrink the request distributions",
                cfg.kv_page_size, cfg.kv_pages
            ),
        ));
    }
    Ok(())
}

/// Run one bounded serving session: load the checkpoint, re-slice it onto
/// the serving mesh, replay the configured traffic, and aggregate.
pub fn serve(manifest: &Manifest, cfg: &ServeConfig) -> Result<ServeReport> {
    let mm = manifest.config(&cfg.model)?;
    let plan = ParallelismPlan::new(cfg.topo);
    plan.validate_serve(mm)?;
    validate_config(cfg, mm)?;
    let (params, resumed_step) = load_params(mm, &cfg.ckpt_dir)?;

    let topo = cfg.topo;
    let world = topo.world();
    let engine = Engine::new_pool(if cfg.engine_pool == 0 { world } else { cfg.engine_pool })?;
    let mesh = Mesh::new(topo);
    let (rxs, traffic_handle) = traffic::spawn(cfg.traffic.clone(), world, mm.hyper.vocab_size)?;
    // Arc-backed: fused lanes share one copy, EP lanes slice their shard
    let full = Tensor::f32(params, vec![mm.param_count]);

    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(world);
    for (rank, rx) in rxs.into_iter().enumerate() {
        let mm = mm.clone();
        let engine = engine.clone();
        let mesh = Arc::clone(&mesh);
        let full = full.clone();
        let mode = cfg.mode;
        let (kv_pages, kv_page_size) = (cfg.kv_pages, cfg.kv_page_size);
        let h = std::thread::Builder::new()
            .name(format!("serve-rank-{rank}"))
            .spawn(move || -> Result<LaneReport> {
                let lane = || -> Result<LaneReport> {
                    let decoder = if topo.ep == 1 {
                        Decoder::Fused(FusedDecoder::new(&mm, full.clone())?)
                    } else {
                        let (group, ep_rank) = mesh.ep_group(rank);
                        Decoder::Ep(EpDecoder::new(
                            &mm,
                            topo.ep,
                            ep_rank,
                            full.as_f32()?,
                            Arc::clone(group),
                        )?)
                    };
                    let lockstep = (topo.ep > 1).then(|| {
                        let (group, ep_rank) = mesh.ep_group(rank);
                        (Arc::clone(group), ep_rank)
                    });
                    scheduler::run_lane(
                        &engine,
                        &decoder,
                        KvPool::new(kv_pages, kv_page_size),
                        rx,
                        mode,
                        mm.hyper.batch,
                        lockstep,
                    )
                };
                let r = lane();
                if r.is_err() {
                    // dead lane: unblock EP siblings parked in lockstep
                    // collectives instead of hanging the session
                    mesh.poison_all();
                }
                r
            })
            .expect("spawn serve rank");
        handles.push(h);
    }

    let mut lanes: Vec<LaneReport> = Vec::with_capacity(world);
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(lr)) => lanes.push(lr),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some(anyhow::anyhow!("serve rank thread panicked"));
                }
            }
        }
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    // the producer exits once every send landed or any lane hung up
    let _ = traffic_handle.join();
    if let Some(e) = first_err {
        return Err(e);
    }

    let mut report = ServeReport {
        submitted: cfg.traffic.requests,
        wall_secs,
        kv_pages_total: cfg.kv_pages * world,
        resumed_step,
        ..ServeReport::default()
    };
    for lr in lanes {
        report.completions.extend(lr.completions);
        report.ttft.merge(&lr.ttft);
        report.per_token.merge(&lr.per_token);
        report.tokens_generated += lr.tokens_generated;
        report.decode_steps += lr.decode_steps;
        report.kv_pages_leaked += lr.pages_leaked;
        report.kv_pages_peak += lr.pages_peak;
    }
    report.completions.sort_by_key(|c| c.id);
    Ok(report)
}
