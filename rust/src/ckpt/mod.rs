//! Checkpointing (paper §4): dual checkpointing, persistent model-only
//! checkpoints, and DP-scattered checkpoint writes.
//!
//! Checkpoint = params (+ optional optimizer moments) + JSON metadata with
//! a content checksum, so a half-written checkpoint is detected and the
//! *other* slot of the dual pair is used — the paper's guarantee that "a
//! valid checkpoint to resume training" always exists.

use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// FNV-1a over the byte image — cheap corruption detection.
fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|w| f32::from_le_bytes(w.try_into().unwrap()))
        .collect()
}

/// Full or model-only checkpoint payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: usize,
    pub params: Vec<f32>,
    /// optimizer moments (empty for model-only checkpoints; the paper
    /// restarts such checkpoints with fresh optimizer state)
    pub moments: Vec<f32>,
    /// serialized parallelism-plan fingerprint (see
    /// [`crate::coordinator::JobSpec::fingerprint`]); `None` for legacy
    /// checkpoints written before plans were recorded
    pub plan: Option<String>,
}

impl Checkpoint {
    /// Model-only checkpoint from an `Arc`-backed parameter tensor (e.g.
    /// [`crate::coordinator::TrainReport::final_params`]). The single copy
    /// here is the serialization boundary — nothing upstream cloned.
    pub fn model_only(step: usize, params: &crate::runtime::Tensor) -> Result<Checkpoint> {
        Ok(Checkpoint { step, params: params.to_f32_vec()?, moments: Vec::new(), plan: None })
    }

    /// Record the plan fingerprint this checkpoint was trained under.
    pub fn with_plan(mut self, fingerprint: &str) -> Checkpoint {
        self.plan = Some(fingerprint.to_string());
        self
    }

    /// Resume-compatibility gate: a checkpoint that recorded a plan must
    /// match the plan resuming it on every *state-relevant* field —
    /// model, dp×ep×pp topology and sharding mode (the first three
    /// segments of the fingerprint). Execution knobs that don't shape
    /// checkpoint state (schedule, microbatch count, exchange policy) may
    /// differ freely. Resharding is out of scope — a mismatch is a clear
    /// error, never silent corruption. Legacy checkpoints (no recorded
    /// plan) pass.
    pub fn ensure_plan(&self, expected: &str) -> Result<()> {
        let state_key = |fp: &str| -> Vec<String> {
            // fingerprint shape: model/dpX-epY-ppZ/mode/schedule/mbN/comm
            fp.split('/').take(3).map(str::to_string).collect()
        };
        match &self.plan {
            Some(p) if state_key(p) != state_key(expected) => Err(anyhow!(
                "checkpoint parallelism plan mismatch: saved under `{p}`, \
                 resuming with `{expected}` — resharding is not supported; \
                 resume with the matching model/topology/sharding or \
                 restart from a model-only checkpoint"
            )),
            _ => Ok(()),
        }
    }

    pub fn is_model_only(&self) -> bool {
        self.moments.is_empty()
    }

    pub fn write(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let pbytes = f32s_to_bytes(&self.params);
        let mbytes = f32s_to_bytes(&self.moments);
        std::fs::write(dir.join("params.bin"), &pbytes)?;
        std::fs::write(dir.join("moments.bin"), &mbytes)?;
        let mut meta = BTreeMap::new();
        meta.insert("step".to_string(), Json::Num(self.step as f64));
        meta.insert("params_len".to_string(), Json::Num(self.params.len() as f64));
        meta.insert("moments_len".to_string(), Json::Num(self.moments.len() as f64));
        if let Some(plan) = &self.plan {
            meta.insert("plan".to_string(), Json::Str(plan.clone()));
        }
        meta.insert(
            "checksum".to_string(),
            Json::Str(format!("{:016x}", checksum(&pbytes) ^ checksum(&mbytes))),
        );
        // metadata written LAST: its presence + matching checksum marks a
        // complete checkpoint
        std::fs::write(dir.join("meta.json"), Json::Obj(meta).to_string())?;
        Ok(())
    }

    pub fn read(dir: &Path) -> Result<Checkpoint> {
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("no metadata in {dir:?}"))?;
        let meta = Json::parse(&meta_text).map_err(|e| anyhow!("{e}"))?;
        let pbytes = std::fs::read(dir.join("params.bin"))?;
        let mbytes = std::fs::read(dir.join("moments.bin"))?;
        let want = meta.req("checksum").as_str().unwrap_or("").to_string();
        let got = format!("{:016x}", checksum(&pbytes) ^ checksum(&mbytes));
        if want != got {
            return Err(anyhow!("checksum mismatch in {dir:?}"));
        }
        Ok(Checkpoint {
            step: meta.req("step").as_usize().unwrap(),
            params: bytes_to_f32s(&pbytes),
            moments: bytes_to_f32s(&mbytes),
            plan: meta
                .get("plan")
                .and_then(|p| p.as_str())
                .map(|s| s.to_string()),
        })
    }
}

/// Dual checkpointing (paper §4): two slots, write to the *older* one, so
/// a failure mid-write never destroys the only valid checkpoint.
pub struct DualCheckpointer {
    root: PathBuf,
}

impl DualCheckpointer {
    pub fn new(root: &Path) -> DualCheckpointer {
        DualCheckpointer { root: root.to_path_buf() }
    }

    pub fn slot_dir(&self, slot: usize) -> PathBuf {
        self.root.join(format!("ckpt-{}", slot + 1))
    }

    fn slot_step(&self, slot: usize) -> Option<usize> {
        Checkpoint::read(&self.slot_dir(slot)).ok().map(|c| c.step)
    }

    /// Slot chosen for the next write: the invalid one, else the older.
    pub fn next_slot(&self) -> usize {
        match (self.slot_step(0), self.slot_step(1)) {
            (None, _) => 0,
            (_, None) => 1,
            (Some(a), Some(b)) => {
                if a <= b {
                    0
                } else {
                    1
                }
            }
        }
    }

    pub fn save(&self, ckpt: &Checkpoint) -> Result<PathBuf> {
        let dir = self.slot_dir(self.next_slot());
        // remove stale metadata first so a crash mid-write leaves the slot
        // *invalid* rather than stale-but-valid-looking
        let _ = std::fs::remove_file(dir.join("meta.json"));
        ckpt.write(&dir)?;
        Ok(dir)
    }

    /// Newest valid checkpoint, if any.
    pub fn load_latest(&self) -> Option<Checkpoint> {
        let a = Checkpoint::read(&self.slot_dir(0)).ok();
        let b = Checkpoint::read(&self.slot_dir(1)).ok();
        match (a, b) {
            (Some(x), Some(y)) => Some(if x.step >= y.step { x } else { y }),
            (Some(x), None) => Some(x),
            (None, Some(y)) => Some(y),
            (None, None) => None,
        }
    }
}

/// Persistent model-only checkpoints (paper §4): params only (4 bytes vs
/// 12 bytes/param here; the paper quotes 8× for BF16+AdamW), kept at every
/// interval forever so training can rewind past a divergence.
pub struct PersistentCheckpointer {
    root: PathBuf,
}

impl PersistentCheckpointer {
    pub fn new(root: &Path) -> PersistentCheckpointer {
        PersistentCheckpointer { root: root.to_path_buf() }
    }

    pub fn save(&self, step: usize, params: &[f32]) -> Result<PathBuf> {
        let dir = self.root.join(format!("model-{step:08}"));
        Checkpoint { step, params: params.to_vec(), moments: Vec::new(), plan: None }
            .write(&dir)?;
        Ok(dir)
    }

    /// All persisted steps, sorted.
    pub fn steps(&self) -> Vec<usize> {
        let mut v: Vec<usize> = std::fs::read_dir(&self.root)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        e.file_name()
                            .to_str()
                            .and_then(|n| n.strip_prefix("model-").map(String::from))
                    })
                    .filter_map(|s| s.parse().ok())
                    .collect()
            })
            .unwrap_or_default();
        v.sort_unstable();
        v
    }

    /// Load the newest model-only checkpoint at or before `step` — the
    /// paper's "track back to a good training regime".
    pub fn load_at_or_before(&self, step: usize) -> Option<Checkpoint> {
        let s = *self.steps().iter().filter(|&&s| s <= step).next_back()?;
        Checkpoint::read(&self.root.join(format!("model-{s:08}"))).ok()
    }
}

/// DP-scattered model checkpointing (paper §4): model-parallel shard `m`
/// is written by DP index `d = m % DP`, spreading filesystem load.
pub fn dp_scattered_assignment(n_shards: usize, dp: usize) -> Vec<usize> {
    (0..n_shards).map(|m| m % dp).collect()
}

/// Write model-parallel shards with the scattered assignment; `my_dp` only
/// writes the shards it owns. Shard files carry their own checksums.
pub fn write_scattered_shards(
    root: &Path,
    my_dp: usize,
    dp: usize,
    shards: &[(usize, Vec<f32>)],
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(root)?;
    let mut written = Vec::new();
    for (m, data) in shards {
        if m % dp != my_dp {
            continue;
        }
        let bytes = f32s_to_bytes(data);
        let path = root.join(format!("shard-{m:04}.bin"));
        std::fs::write(&path, &bytes)?;
        let meta = format!(
            "{{\"shard\":{m},\"writer_dp\":{my_dp},\"checksum\":\"{:016x}\"}}",
            checksum(&bytes)
        );
        std::fs::write(root.join(format!("shard-{m:04}.json")), meta)?;
        written.push(path);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("optimus-ck-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn ck(step: usize) -> Checkpoint {
        Checkpoint {
            step,
            params: (0..64).map(|i| i as f32 + step as f32).collect(),
            moments: vec![0.5; 128],
            plan: None,
        }
    }

    #[test]
    fn plan_fingerprint_roundtrips_and_gates_resume() {
        let d = tmp("plan");
        let fp = "mula-tiny/dp1-ep2-pp2/epso/1f1b/mb2/allgather";
        ck(5).with_plan(fp).write(&d).unwrap();
        let c = Checkpoint::read(&d).unwrap();
        assert_eq!(c.plan.as_deref(), Some(fp));
        // matching plan resumes
        c.ensure_plan(fp).unwrap();
        // execution knobs that don't shape checkpoint state may change
        c.ensure_plan("mula-tiny/dp1-ep2-pp2/epso/gpipe/mb4/all2all")
            .unwrap();
        // topology changes are a clear error, not corruption
        let e = c
            .ensure_plan("mula-tiny/dp2-ep1-pp1/so/1f1b/mb2/allgather")
            .unwrap_err()
            .to_string();
        assert!(e.contains("parallelism plan mismatch"), "{e}");
        assert!(e.contains(fp), "{e}");
        // legacy checkpoints without a recorded plan always pass
        let legacy = ck(5);
        legacy.ensure_plan(fp).unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn roundtrip_and_corruption_detection() {
        let d = tmp("rt");
        ck(7).write(&d).unwrap();
        assert_eq!(Checkpoint::read(&d).unwrap(), ck(7));
        let mut b = std::fs::read(d.join("params.bin")).unwrap();
        b[3] ^= 0xff;
        std::fs::write(d.join("params.bin"), b).unwrap();
        assert!(Checkpoint::read(&d).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn dual_alternates_and_survives_failed_write() {
        let d = tmp("dual");
        let dual = DualCheckpointer::new(&d);
        assert!(dual.load_latest().is_none());
        dual.save(&ck(1000)).unwrap();
        dual.save(&ck(2000)).unwrap();
        // next write goes to the *older* slot (holding step 1000)
        let slot = dual.next_slot();
        assert_eq!(dual.slot_step(slot), Some(1000));
        // simulate a crash mid-write at step 3000
        let dir = dual.slot_dir(slot);
        let _ = std::fs::remove_file(dir.join("meta.json"));
        std::fs::write(dir.join("params.bin"), b"garbage").unwrap();
        // the other slot (step 2000) must still load
        let latest = dual.load_latest().unwrap();
        assert_eq!(latest.step, 2000);
        // recovery resumes the alternation
        dual.save(&ck(3000)).unwrap();
        assert_eq!(dual.load_latest().unwrap().step, 3000);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn persistent_rewinds_past_divergence() {
        let d = tmp("persist");
        let p = PersistentCheckpointer::new(&d);
        for step in [1000, 2000, 3000] {
            p.save(step, &ck(step).params).unwrap();
        }
        assert_eq!(p.steps(), vec![1000, 2000, 3000]);
        // diverged at 2500: rewind to 2000, fresh optimizer state
        let c = p.load_at_or_before(2500).unwrap();
        assert_eq!(c.step, 2000);
        assert!(c.is_model_only());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn scattered_assignment_spreads_writers() {
        // paper's example: 12-way model parallelism on 12 nodes
        let a = dp_scattered_assignment(12, 12);
        assert_eq!(a, (0..12).collect::<Vec<usize>>());
        let a = dp_scattered_assignment(8, 4);
        for d in 0..4 {
            assert_eq!(a.iter().filter(|&&x| x == d).count(), 2);
        }
    }

    #[test]
    fn scattered_writes_only_owned_shards() {
        let d = tmp("scat");
        let shards: Vec<(usize, Vec<f32>)> =
            (0..6).map(|m| (m, vec![m as f32; 8])).collect();
        for my in 0..3 {
            assert_eq!(write_scattered_shards(&d, my, 3, &shards).unwrap().len(), 2);
        }
        assert_eq!(std::fs::read_dir(&d).unwrap().count(), 12);
        std::fs::remove_dir_all(&d).unwrap();
    }
}
