//! Integration: shared-harness failure semantics, identical across all
//! four topologies (paper §4 hard-failure handling) — a rank returning
//! `Err` mid-step poisons the mesh, peers unblock instead of hanging, and
//! `train()` surfaces the *root-cause* error (never a peer's panic) —
//! plus the zero-copy contract of the `Arc`-backed parameter tensor.

use optimus::comm::{CollectiveOp, CommFault, Group, Reduce, ReduceDtype, Topology};
use optimus::coordinator::{self, JobSpec};
use optimus::ft::{classify, FailureKind, HardKillHook};
use optimus::runtime::{Engine, Tensor};
use std::path::PathBuf;
use std::sync::Arc;
use std::sync::OnceLock;

fn data_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("optimus-hf-data-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = optimus::data::corpus::data_files(42, 3, 16);
        optimus::data::preprocess::preprocess(&files, 64, 7, &dir, 256).unwrap();
        dir
    })
    .clone()
}

/// Kill rank 1 at step 2 and check the harness's failure contract.
fn assert_root_cause_surfaces(topo: Topology, label: &str) {
    let Some(m) = optimus::manifest_or_skip(&format!("harness_failures::{label}")) else {
        return;
    };
    let spec = JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topo(topo)
        .steps(6)
        .warmup_steps(2)
        .engine_pool(2)
        .micro_batches(2)
        .hook(Arc::new(HardKillHook::once(1, 2)))
        .build()
        .unwrap();
    let t0 = std::time::Instant::now();
    let err = coordinator::train(&m, &spec).unwrap_err();
    let msg = format!("{err:#}");
    // root cause, not a peer panic
    assert!(msg.contains("rank 1"), "{label}: wrong rank in `{msg}`");
    assert!(
        msg.contains("injected hard failure"),
        "{label}: not the root cause: `{msg}`"
    );
    assert!(!msg.contains("panicked"), "{label}: peer panic surfaced: `{msg}`");
    assert_eq!(classify(&err), FailureKind::Hard, "{label}: {msg}");
    // peers unblocked: join returned promptly rather than hanging on a
    // collective / p2p recv that will never complete (budget is CI-scaled
    // so shared-runner contention can't flake this wall-clock bound)
    assert!(
        t0.elapsed() < optimus::util::time_budget_secs(60),
        "{label}: peers took {:?} to unblock",
        t0.elapsed()
    );
}

#[test]
fn dp_failure_poisons_mesh_and_surfaces_root_cause() {
    assert_root_cause_surfaces(Topology::dp_only(2), "dp");
}

#[test]
fn ep_failure_poisons_mesh_and_surfaces_root_cause() {
    assert_root_cause_surfaces(Topology::grid(1, 2, 1), "ep");
}

#[test]
fn pp_failure_poisons_mesh_and_surfaces_root_cause() {
    assert_root_cause_surfaces(Topology::grid(1, 1, 2), "pp");
}

#[test]
fn pp_ep_hybrid_failure_poisons_mesh_and_surfaces_root_cause() {
    // in the hybrid topology a dead rank blocks peers on BOTH fabrics —
    // ep-group collectives and p2p stage channels; poisoning must unblock
    // both and still surface the root cause
    assert_root_cause_surfaces(Topology::grid(1, 2, 2), "pp_ep");
}

// ---- protocol auditor + watchdog (artifact-free: drive the fabric
// directly, so these always run) ------------------------------------

/// Two ranks in *different program orders* on the same group: rank 0
/// issues an allreduce where rank 1 issues an allgather. Pre-auditor
/// this was the classic silent deadlock (each waits for a deposit shaped
/// like its own op); now whoever arrives second fails the round with the
/// stable `[order]` violation, the group poisons, and the compliant peer
/// unblocks — classified as a non-relaunchable program bug.
#[test]
fn divergent_program_order_is_an_order_violation_not_a_deadlock() {
    let g = Group::new_labeled(2, "hf-order");
    let t0 = std::time::Instant::now();
    let a = {
        let g = Arc::clone(&g);
        std::thread::Builder::new()
            .name("hf-order-0".into())
            .spawn(move || {
                g.run(
                    0,
                    CollectiveOp::Allreduce {
                        data: vec![1.0, 2.0],
                        red: Reduce::Sum,
                        dt: ReduceDtype::F32,
                    },
                )
            })
            .unwrap()
    };
    let b = {
        let g = Arc::clone(&g);
        std::thread::Builder::new()
            .name("hf-order-1".into())
            .spawn(move || {
                g.run(1, CollectiveOp::Allgather { data: vec![3.0], dt: ReduceDtype::F32 })
            })
            .unwrap()
    };
    let faults = [
        a.join().unwrap().unwrap_err(),
        b.join().unwrap().unwrap_err(),
    ];
    assert!(
        t0.elapsed() < optimus::util::time_budget_secs(60),
        "order violation must fail fast, not ride the watchdog: {:?}",
        t0.elapsed()
    );
    let msgs: Vec<String> = faults.iter().map(|f| f.to_string()).collect();
    assert!(
        msgs.iter()
            .any(|m| m.contains("collective protocol violated [order]")),
        "{msgs:?}"
    );
    // the violation names both ops — attributable at a glance
    let v = msgs.iter().find(|m| m.contains("[order]")).unwrap();
    assert!(v.contains("allreduce") && v.contains("allgather"), "{v}");
    // deterministic program bug → Config (relaunch replays it identically)
    let fault = faults
        .iter()
        .find(|f| matches!(f, CommFault::Violated { .. }))
        .unwrap();
    assert_eq!(
        classify(&anyhow::anyhow!("{fault}")),
        FailureKind::Config,
        "{fault}"
    );
}

/// A peer that never shows up: the waiter's watchdog expires, fails with
/// the stable `[stall]` string and dumps the per-rank last-op table
/// (who deposited what, who was never seen) — the scale-debugging
/// breadcrumb the paper's hang postmortems need. Stalls classify Hard:
/// the dominant cause is a dead peer, which a relaunch on a buffer node
/// fixes.
#[test]
fn stalled_peer_fails_with_a_per_rank_last_op_dump() {
    let g = Group::new_labeled(2, "hf-stall");
    g.set_stall_timeout(std::time::Duration::from_millis(100));
    let e = g
        .run(
            0,
            CollectiveOp::Allreduce {
                data: vec![1.0],
                red: Reduce::Sum,
                dt: ReduceDtype::F32,
            },
        )
        .unwrap_err();
    let msg = e.to_string();
    assert!(msg.contains("collective protocol violated [stall]"), "{msg}");
    assert!(msg.contains("rank 0 waiting on allreduce"), "{msg}");
    assert!(msg.contains("rank 1 never deposited"), "{msg}");
    assert!(msg.contains("hf-stall"), "{msg}");
    assert_eq!(
        classify(&anyhow::anyhow!("{msg}")),
        FailureKind::Hard,
        "{msg}"
    );
}

#[test]
fn resubmitted_params_tensor_is_never_copied() {
    let Some(m) = optimus::manifest_or_skip("harness_failures::zero_copy_exec") else {
        return;
    };
    let mm = m.config("mula-tiny").unwrap();
    let engine = Engine::new().unwrap();
    let params = Tensor::f32(
        coordinator::init_global_params(mm, 7),
        vec![mm.param_count],
    );
    let ptr = params.data_ptr();
    let toks = Tensor::i32(
        vec![1; mm.hyper.batch * (mm.hyper.seq + 1)],
        vec![mm.hyper.batch, mm.hyper.seq + 1],
    );
    let art = mm.artifact_path("eval_step").unwrap();
    for _ in 0..3 {
        let submitted = params.clone();
        assert!(
            submitted.ptr_eq(&params),
            "submitting to exec must be an Arc bump, not a data copy"
        );
        engine
            .exec("zc:eval", art.clone(), vec![submitted, toks.clone()])
            .unwrap();
    }
    assert_eq!(params.data_ptr(), ptr, "re-submission must not reallocate");
    // the engine dropped its handles when exec returned, so the optimizer's
    // copy-on-write mutation path stays in place — the zero-copy steady state
    let mut params = params;
    params.as_f32_mut().unwrap()[0] += 1.0;
    assert_eq!(params.data_ptr(), ptr, "sole-owner mutation must not copy");
}

#[test]
fn training_report_params_share_storage_with_eval_submissions() {
    let Some(m) = optimus::manifest_or_skip("harness_failures::report_params_zero_copy")
    else {
        return;
    };
    let spec = JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topology(2, 1, 1)
        .steps(3)
        .warmup_steps(1)
        .engine_pool(2)
        .build()
        .unwrap();
    let r = coordinator::train(&m, &spec).unwrap();
    // the report's final params flow into eval without a copy
    let handed_to_eval = r.final_params.clone();
    assert!(handed_to_eval.ptr_eq(&r.final_params));
}
