//! Table 3 (FSMOE column): FastSparseMoE vs the HF-style naive SparseMoE
//! block, fwd+bwd wall time via the real artifacts, plus the Aurora-model
//! projection for the paper-scale configs.
//!
//! The measured rows use our runnable Mula analogs; the *shape* to match
//! Table 3 is: FSMOE wins everywhere, more when experts-per-rank are many
//! relative to top-k.

use optimus::cluster::fsmoe_fwdbwd_speedup;
use optimus::config::models::{MULA_100B, MULA_20B, MULA_220B, MULA_7B};
use optimus::config::Manifest;
use optimus::runtime::{Engine, Tensor};
use optimus::util::bench::{bench_result, fmt_dur, Report};
use optimus::util::prng::Prng;

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let engine = Engine::new()?;
    let mut rep = Report::new(
        "Table 3 — FastSparseMoE fwd+bwd speedup (measured on this testbed)",
        &["model", "experts(top-k)", "naive", "fsmoe", "speedup"],
    );

    for name in ["mula-tiny", "mula-mini", "mula-small"] {
        let mm = m.config(name)?;
        let h = &mm.hyper;
        let t = h.batch * h.seq;
        let blk_info = mm.artifact("moe_block_fsmoe")?;
        let blk_n = blk_info.inputs[0].shape[0];
        let mut rng = Prng::new(5);
        let bp: Vec<f32> = (0..blk_n).map(|_| rng.normal_f32() * 0.05).collect();
        let x: Vec<f32> = (0..t * h.hidden).map(|_| rng.normal_f32()).collect();
        let dy: Vec<f32> = (0..t * h.hidden).map(|_| rng.normal_f32()).collect();
        let inputs = || {
            vec![
                Tensor::f32(bp.clone(), vec![blk_n]),
                Tensor::f32(x.clone(), vec![t, h.hidden]),
                Tensor::f32(dy.clone(), vec![t, h.hidden]),
            ]
        };
        let time = |key: &str| {
            let path = mm.artifact_path(key).unwrap();
            bench_result(1, 4, || {
                engine
                    .exec(&format!("{name}:{key}"), path.clone(), inputs())
                    .map(|_| ())
            })
        };
        let naive = time("moe_block_naive")?;
        let fast = time("moe_block_fsmoe")?;
        rep.row(&[
            name.into(),
            format!("{}({})", h.n_experts, h.top_k),
            fmt_dur(naive.median),
            fmt_dur(fast.median),
            format!("{:.2}x", naive.median_secs() / fast.median_secs()),
        ]);
    }
    rep.print();
    rep.write_csv("table3_fsmoe").ok();

    let mut proj = Report::new(
        "Table 3 — FSMOE projection at paper scale (Aurora model)",
        &["model", "EP", "paper F+B", "modeled F+B"],
    );
    for (spec, ep, paper) in [
        (&MULA_7B, 1usize, 2.83),
        (&MULA_20B, 12, 1.33),
        (&MULA_100B, 12, 1.51),
        (&MULA_220B, 12, 1.66),
    ] {
        proj.row(&[
            spec.name.into(),
            ep.to_string(),
            format!("{paper:.2}x"),
            format!("{:.2}x", fsmoe_fwdbwd_speedup(spec, ep, 64)),
        ]);
    }
    proj.print();
    proj.write_csv("table3_fsmoe_projection").ok();
    Ok(())
}
