//! The Optimus trainer: multi-rank DP / EP / PP training orchestration.
//!
//! One OS thread per rank; real HLO execution per rank through the PJRT
//! [`crate::runtime::Engine`]; real collectives through [`crate::comm`].
//! All topologies run on the shared rank-execution [`harness`], which owns
//! spawning, failure poisoning, model broadcasting, the per-step driver
//! loop and report assembly; a parallelism engine is one
//! [`harness::RankTrainer`] impl holding only its distinct logic.
//! Three runnable engines (matching the paper's experiments, §2):
//!
//! * **DP (fused)** — every rank runs the fused `train_step` artifact;
//!   gradient sync + sharded AdamW via [`crate::optim::ShardedOptimizer`].
//! * **EP** — per-layer execution with Stage-1 token exchange in Rust
//!   (allgather or all2all), FastSparseMoE expert artifacts per rank, and
//!   SO/EPSO sharding (§3.2).
//! * **PP** — GPipe / 1F1B microbatch schedules over stage artifacts with
//!   activations over point-to-point channels; backward recomputes from
//!   stashed stage inputs (selective activation checkpointing, §1).

pub mod ep;
pub mod harness;
pub mod pipeline;

mod ep_layout;
mod train_dp;
mod train_ep;
mod train_pp;

pub use ep_layout::EpLayout;

use crate::comm::{Mesh, ReduceDtype, Topology};
use crate::config::{Manifest, ModelManifest, RunConfig};
use crate::data::Dataset;
use crate::metrics::{Curve, StepBreakdown};
use crate::optim::{AdamParams, ShardingMode};
use crate::runtime::{Engine, Tensor};
use crate::util::prng::Prng;
use crate::Result;
use anyhow::anyhow;
use std::path::PathBuf;
use std::sync::Arc;

/// Per-step callback for checkpointing / fault injection / NaN handling.
/// Returning `Err` aborts the rank (simulating a failure the launcher
/// must handle).
pub trait StepHook: Send + Sync {
    fn on_step(
        &self,
        rank: usize,
        step: usize,
        loss: f32,
        params: &mut [f32],
    ) -> Result<()> {
        let _ = (rank, step, loss, params);
        Ok(())
    }
}

/// No-op hook.
pub struct NoHook;
impl StepHook for NoHook {}

#[derive(Clone)]
pub struct TrainOptions {
    pub model: String,
    pub topo: Topology,
    pub mode: ShardingMode,
    pub run: RunConfig,
    /// forced uniform routing (paper §2.3)
    pub fur: bool,
    /// Stage-1 exchange policy (paper §3.1 Stage 1 ablation)
    pub ep_comm: ep::EpComm,
    pub schedule: pipeline::Schedule,
    /// microbatches per step (PP)
    pub micro_batches: usize,
    /// PJRT executor threads
    pub engine_pool: usize,
    /// preprocessed shard directory
    pub data_dir: PathBuf,
    pub hook: Arc<dyn StepHook>,
}

impl TrainOptions {
    pub fn new(model: &str, topo: Topology, data_dir: PathBuf) -> TrainOptions {
        TrainOptions {
            model: model.into(),
            topo,
            mode: ShardingMode::Epso,
            run: RunConfig::default(),
            fur: false,
            ep_comm: ep::EpComm::Allgather,
            schedule: pipeline::Schedule::OneFOneB,
            micro_batches: 2,
            engine_pool: 2,
            data_dir,
            hook: Arc::new(NoHook),
        }
    }

    pub fn adam(&self) -> AdamParams {
        AdamParams {
            beta1: self.run.beta1 as f32,
            beta2: self.run.beta2 as f32,
            eps: self.run.eps as f32,
            weight_decay: self.run.weight_decay as f32,
        }
    }

    pub fn reduce_dtype(&self) -> ReduceDtype {
        if self.run.bf16_grad_reduce {
            ReduceDtype::Bf16
        } else {
            ReduceDtype::F32
        }
    }
}

/// Result of a training run (aggregated over ranks).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub loss: Curve,
    pub grad_norm: Curve,
    pub breakdown: StepBreakdown,
    pub step_secs: Vec<f64>,
    pub tokens_per_step: usize,
    /// final full parameter vector (rank 0's view) for eval/checkpoints —
    /// `Arc`-backed, so passing it on to [`crate::eval::run_suite`] or a
    /// checkpoint writer involves no copy
    pub final_params: Tensor,
    /// optimizer state bytes per rank (Figure 6 quantity)
    pub opt_state_bytes: usize,
    pub optimizer_update_secs: f64,
    pub optimizer_comm_secs: f64,
}

impl TrainReport {
    pub fn tokens_per_sec(&self) -> f64 {
        let total: f64 = self.step_secs.iter().sum();
        if total == 0.0 {
            return 0.0;
        }
        (self.tokens_per_step * self.step_secs.len()) as f64 / total
    }

    pub fn mean_step_secs(&self) -> f64 {
        if self.step_secs.is_empty() {
            return 0.0;
        }
        // skip the first (compile) step
        let s: Vec<f64> = self.step_secs.iter().skip(1).copied().collect();
        if s.is_empty() {
            return self.step_secs[0];
        }
        s.iter().sum::<f64>() / s.len() as f64
    }
}

/// Deterministic parameter init (distribution-parity with python's
/// `model.init_params`): N(0, 0.02) everywhere, 1.0 for norm gains.
pub fn init_global_params(mm: &ModelManifest, seed: u64) -> Vec<f32> {
    let mut flat = vec![0.0f32; mm.param_count];
    let mut rng = Prng::new(seed).fork(17);
    for spec in &mm.params {
        let seg = &mut flat[spec.offset..spec.offset + spec.numel];
        if spec.name.contains("norm") {
            seg.fill(1.0);
        } else {
            for v in seg.iter_mut() {
                *v = rng.normal_f32() * 0.02;
            }
        }
    }
    flat
}

/// Entry point: dispatch on topology. Every topology runs through the
/// shared [`harness`]; the dispatch only picks which [`harness::RankTrainer`]
/// impl drives the ranks.
pub fn train(manifest: &Manifest, opts: &TrainOptions) -> Result<TrainReport> {
    let mm = manifest.config(&opts.model)?;
    let ds = Arc::new(Dataset::open(&opts.data_dir)?);
    if ds.context < mm.hyper.seq + 1 {
        return Err(anyhow!(
            "data context {} < model seq+1 {}",
            ds.context,
            mm.hyper.seq + 1
        ));
    }
    let engine = Engine::new_pool(opts.engine_pool)?;
    let mesh = Mesh::new(opts.topo);
    if opts.topo.pp > 1 {
        if opts.topo.ep > 1 {
            return Err(anyhow!(
                "runnable engine supports PP×EP separately; combined PP×EP \
                 is covered by the cluster model (see DESIGN.md §9)"
            ));
        }
        harness::run::<train_pp::PpTrainer>(mm, ds, engine, mesh, opts)
    } else if opts.topo.ep > 1 {
        harness::run::<train_ep::EpTrainer>(mm, ds, engine, mesh, opts)
    } else {
        harness::run::<train_dp::DpTrainer>(mm, ds, engine, mesh, opts)
    }
}

/// Should this step clip (paper: clipping only after warmup)?
pub(crate) fn clip_now(run: &RunConfig, step: usize) -> bool {
    !run.clip_after_warmup_only || step >= run.warmup_steps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic_and_scaled() {
        let Some(m) = crate::manifest_or_skip("coordinator::init_is_deterministic_and_scaled")
        else {
            return;
        };
        let mm = m.config("mula-tiny").unwrap();
        let a = init_global_params(mm, 5);
        let b = init_global_params(mm, 5);
        assert_eq!(a, b);
        let c = init_global_params(mm, 6);
        assert_ne!(a, c);
        // norms are ones
        let norm_spec = mm.params.iter().find(|p| p.name.contains("norm1")).unwrap();
        assert!(a[norm_spec.offset..norm_spec.offset + norm_spec.numel]
            .iter()
            .all(|&v| v == 1.0));
        // weights roughly N(0, 0.02)
        let emb = &a[0..mm.params[0].numel];
        let mean: f32 = emb.iter().sum::<f32>() / emb.len() as f32;
        let var: f32 =
            emb.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / emb.len() as f32;
        assert!(mean.abs() < 2e-3, "{mean}");
        assert!((var.sqrt() - 0.02).abs() < 2e-3, "{}", var.sqrt());
    }
}
