//! Kill-and-resume equivalence (paper §4): hard-kill a rank mid-run,
//! auto-resume from the async sharded checkpoint, and the final
//! parameters are **bit-identical** to an uninterrupted run — across the
//! DP, EP and PP×EP topologies. Plus the elastic cases: a checkpoint
//! written under dp2×ep2 resumes under dp4 (and vice versa) through
//! `ckpt::reshard`, continuing with the trajectory the new topology
//! would produce from the same global state.

use optimus::comm::Topology;
use optimus::coordinator::{self, DataTrace, JobSpec, JobSpecBuilder, TrainReport};
use optimus::data::{corpus, preprocess, Dataset};
use optimus::ft::{HardKillHook, Launcher};
use optimus::optim::ShardingMode;
use optimus::runtime::Dtype;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

fn data_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("optimus-kr-data-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = corpus::data_files(42, 4, 24);
        preprocess::preprocess(&files, 64, 7, &dir, 256).unwrap();
        dir
    })
    .clone()
}

fn ckroot(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("optimus-kr-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn base(topo: Topology, steps: usize) -> JobSpecBuilder {
    let mut b = JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topo(topo)
        .steps(steps)
        .warmup_steps(2)
        .peak_lr(2e-3)
        .min_lr(2e-4)
        .engine_pool(2)
        .bf16_grad_reduce(false);
    if topo.ep > 1 {
        b = b.sharding(ShardingMode::Epso);
    }
    b
}

fn assert_bits_eq(tag: &str, a: &TrainReport, b: &TrainReport) {
    let x = a.final_params.as_f32().unwrap();
    let y = b.final_params.as_f32().unwrap();
    assert_eq!(x.len(), y.len(), "{tag}: param count");
    for (i, (p, q)) in x.iter().zip(y.iter()).enumerate() {
        assert_eq!(
            p.to_bits(),
            q.to_bits(),
            "{tag}: param {i} diverged across kill/resume: {p} vs {q}"
        );
    }
}

fn max_abs_diff(a: &TrainReport, b: &TrainReport) -> f32 {
    a.final_params
        .as_f32()
        .unwrap()
        .iter()
        .zip(b.final_params.as_f32().unwrap().iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap().flatten() {
        let to = dst.join(e.file_name());
        if e.path().is_dir() {
            copy_dir(&e.path(), &to);
        } else {
            std::fs::copy(e.path(), &to).unwrap();
        }
    }
}

/// The satellite acceptance gate: for each topology, a run hard-killed at
/// step 5 and auto-resumed from the step-3 async sharded checkpoint ends
/// with parameters bit-identical to the uninterrupted run — params,
/// AdamW moments and the bias-correction counter all restore exactly.
#[test]
fn kill_and_resume_is_bit_identical_across_topologies() {
    let Some(m) =
        optimus::manifest_or_skip("kill_resume::kill_and_resume_is_bit_identical")
    else {
        return;
    };
    let steps = 9;
    for (tag, topo) in [
        ("dp", Topology::dp_only(2)),
        ("ep", Topology::grid(1, 2, 1)),
        ("ppep", Topology::grid(1, 2, 2)),
    ] {
        // uninterrupted reference (no checkpointing: bit-identity also
        // proves the O(1) snapshot capture never perturbs training)
        let reference = coordinator::train(&m, &base(topo, steps).build().unwrap()).unwrap();

        let ck = ckroot(tag);
        let kill = Arc::new(HardKillHook::once(1, 5));
        let launcher = Launcher::new(topo.world(), 1);
        let resumed = launcher
            .run(|_, nodes| {
                let s = base(topo, steps)
                    .world_size(nodes.len())
                    .hook(kill.clone())
                    .checkpoint_dir(&ck)
                    .ckpt_every(3)
                    .build()?;
                coordinator::train(&m, &s)
            })
            .unwrap();
        assert_eq!(
            launcher.relaunches.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "{tag}: exactly one relaunch"
        );
        // the relaunched attempt really resumed (curve starts at step 4)
        // and kept committing checkpoints afterwards
        assert_eq!(resumed.loss.points.first().unwrap().0, 4, "{tag}");
        assert!(resumed.ckpt_commits >= 1, "{tag}: no commits after resume");
        assert_bits_eq(tag, &resumed, &reference);
        let _ = std::fs::remove_dir_all(&ck);
    }
}

/// Elastic resume, both directions: the dp2×ep2 EPSO checkpoint resumes
/// under dp4 and the dp4 checkpoint under dp2×ep2. The restored global
/// state is bit-identical (asserted at unit level in `ckpt`); continued
/// training matches the native-topology resume to the same fp32
/// reduction tolerance the engines match each other fresh
/// (`train_modes::pp_ep_hybrid_matches_dp_and_learns`).
#[test]
fn elastic_resume_dp2ep2_to_dp4_and_back() {
    let Some(m) = optimus::manifest_or_skip("kill_resume::elastic_resume") else {
        return;
    };
    let pairs = [
        ("to-dp4", Topology::grid(2, 2, 1), Topology::dp_only(4)),
        ("to-dp2ep2", Topology::dp_only(4), Topology::grid(2, 2, 1)),
    ];
    for (tag, save_topo, resume_topo) in pairs {
        // produce a checkpoint at step 6 under the saving topology
        let ck_native = ckroot(&format!("el-{tag}-a"));
        let produced = coordinator::train(
            &m,
            &base(save_topo, 7)
                .checkpoint_dir(&ck_native)
                .ckpt_every(3)
                .build()
                .unwrap(),
        )
        .unwrap();
        assert!(produced.ckpt_commits >= 2, "{tag}: commits at steps 3 and 6");
        let ck_elastic = ckroot(&format!("el-{tag}-b"));
        copy_dir(&ck_native, &ck_elastic);

        // native resume (same topology) vs elastic resume (resharded)
        let native = coordinator::train(
            &m,
            &base(save_topo, 10).checkpoint_dir(&ck_native).build().unwrap(),
        )
        .unwrap();
        let elastic = coordinator::train(
            &m,
            &base(resume_topo, 10).checkpoint_dir(&ck_elastic).build().unwrap(),
        )
        .unwrap();

        // both resumed at step 7 from the same global state
        assert_eq!(native.loss.points.first().unwrap().0, 7, "{tag}");
        assert_eq!(elastic.loss.points.first().unwrap().0, 7, "{tag}");
        for ((_, a), (_, b)) in native.loss.points.iter().zip(elastic.loss.points.iter()) {
            assert!(a.is_finite() && b.is_finite(), "{tag}");
        }
        // identical restored state ⇒ first resumed losses coincide (up
        // to the engines' fp reduction-order differences)
        let (l_n, l_e) = (native.loss.points[0].1, elastic.loss.points[0].1);
        assert!(
            (l_n - l_e).abs() < 2e-3,
            "{tag}: first resumed loss native {l_n} vs elastic {l_e}"
        );
        // ... and trajectories agree to fp32 reduction tolerance
        let d = max_abs_diff(&native, &elastic);
        assert!(d < 1e-2, "{tag}: elastic resume diverged, max |Δparam| = {d}");
        let _ = std::fs::remove_dir_all(&ck_native);
        let _ = std::fs::remove_dir_all(&ck_elastic);
    }
}

/// The PR 5 acceptance gate (recorded-id hook): a run checkpointed
/// mid-epoch and resumed under a **different** topology consumes exactly
/// the unseen stream positions — no re-reads, no gaps — and every
/// instance id at most once per epoch. Covers both the equal-geometry
/// elastic case (dp2×ep2 → dp4) and the geometry-changing one
/// (dp2 → dp4, where the old `step × instances_per_step` derivation
/// skipped half a run's data).
#[test]
fn elastic_resume_consumes_each_instance_exactly_once_data_order() {
    let Some(m) = optimus::manifest_or_skip("kill_resume::elastic_data_order") else {
        return;
    };
    let ds = Dataset::open(&data_dir()).unwrap();
    for (tag, save_topo, resume_topo) in [
        ("dp2ep2-to-dp4", Topology::grid(2, 2, 1), Topology::dp_only(4)),
        ("dp2-to-dp4", Topology::dp_only(2), Topology::dp_only(4)),
    ] {
        let ck = ckroot(&format!("order-{tag}"));
        // run A: 7 steps under the saving topology, checkpoints at 3 & 6
        let trace_a: DataTrace = Arc::new(Mutex::new(Vec::new()));
        let a = coordinator::train(
            &m,
            &base(save_topo, 7)
                .checkpoint_dir(&ck)
                .ckpt_every(3)
                .data_trace(trace_a.clone())
                .build()
                .unwrap(),
        )
        .unwrap();
        assert!(a.ckpt_commits >= 2, "{tag}: commits at steps 3 and 6");
        // run B: elastic resume under the new topology, 3 more steps
        let trace_b: DataTrace = Arc::new(Mutex::new(Vec::new()));
        let b = coordinator::train(
            &m,
            &base(resume_topo, 10)
                .checkpoint_dir(&ck)
                .data_trace(trace_b.clone())
                .build()
                .unwrap(),
        )
        .unwrap();
        assert_eq!(b.loss.points.first().unwrap().0, 7, "{tag}: resumed at step 7");

        let ra = trace_a.lock().unwrap().clone();
        let rb = trace_b.lock().unwrap().clone();
        assert!(!ra.is_empty() && !rb.is_empty(), "{tag}: traces recorded");
        // the whole experiment stays inside one epoch, so "exactly once
        // per run" below is "exactly once per epoch"
        let total = ra.len() + rb.len();
        assert!(
            total <= ds.len(),
            "{tag}: test precondition broken — {total} reads exceed one epoch of {}",
            ds.len()
        );
        // stream positions from both runs tile [0, total) exactly:
        // nothing re-read after the elastic switch, nothing skipped
        let mut pos: Vec<u64> = ra.iter().chain(rb.iter()).map(|r| r.0).collect();
        pos.sort_unstable();
        for (i, p) in pos.iter().enumerate() {
            assert_eq!(
                *p, i as u64,
                "{tag}: stream position {i} was {} (gap or double-read across resume)",
                p
            );
        }
        // ... and the resumed run picked up at exactly A's end
        let b_first = rb.iter().map(|r| r.0).min().unwrap();
        assert_eq!(b_first as usize, ra.len(), "{tag}: resume cursor offset");
        // instance ids: consumed at most once (shuffle is a bijection)
        let mut ids: Vec<u64> = ra.iter().chain(rb.iter()).map(|r| r.1).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "{tag}: an instance id was consumed twice in-epoch");
        let _ = std::fs::remove_dir_all(&ck);
    }
}

/// The shuffled order is reproducible from `--data-seed` alone, and a
/// different data seed reorders the stream without changing its
/// coverage.
#[test]
fn shuffle_order_reproducible_from_data_seed_alone() {
    let Some(m) = optimus::manifest_or_skip("kill_resume::data_seed_reproducibility")
    else {
        return;
    };
    let run = |data_seed: u64, init_seed: u64| {
        let trace: DataTrace = Arc::new(Mutex::new(Vec::new()));
        coordinator::train(
            &m,
            &base(Topology::dp_only(2), 3)
                .seed(init_seed)
                .data_seed(data_seed)
                .data_trace(trace.clone())
                .build()
                .unwrap(),
        )
        .unwrap();
        let mut r = trace.lock().unwrap().clone();
        r.sort_unstable(); // rank interleaving is nondeterministic; order by position
        r
    };
    let a = run(11, 1234);
    let b = run(11, 9999); // different *model* seed: data order must not move
    assert_eq!(a, b, "data order must be a pure function of --data-seed");
    // the recorded ids equal the pure seed-derived stream mapping — the
    // whole order is reconstructible from --data-seed + the dataset
    // (seed-sensitivity of that mapping is asserted at unit level in
    // data::stream / data::shuffle over full epochs)
    let ds = Arc::new(Dataset::open(&data_dir()).unwrap());
    let st = optimus::data::TokenStream::new(ds, 11, u64::MAX);
    for &(p, id) in &a {
        assert_eq!(st.map(p).unwrap().1 as u64, id, "position {p}");
    }
}

/// A checkpoint's token cursor is only valid under the shuffle that
/// consumed it: resuming with a different `--data-seed` is refused with
/// a stable, non-relaunchable error instead of silently re-reading and
/// skipping instances.
#[test]
fn resume_rejects_a_different_data_seed() {
    let Some(m) = optimus::manifest_or_skip("kill_resume::resume_rejects_data_seed") else {
        return;
    };
    let ck = ckroot("data-seed");
    coordinator::train(
        &m,
        &base(Topology::dp_only(2), 5)
            .data_seed(11)
            .checkpoint_dir(&ck)
            .ckpt_every(2)
            .build()
            .unwrap(),
    )
    .unwrap();
    let e = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 8)
            .data_seed(12)
            .checkpoint_dir(&ck)
            .build()
            .unwrap(),
    )
    .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("checkpoint resume failed [data-seed]"), "{msg}");
    assert_eq!(optimus::ft::classify(&e), optimus::ft::FailureKind::Config, "{msg}");
    // the matching seed resumes cleanly from the step-4 checkpoint
    let r = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 8)
            .data_seed(11)
            .checkpoint_dir(&ck)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_eq!(r.loss.points.first().unwrap().0, 5);
    let _ = std::fs::remove_dir_all(&ck);
}

/// Async snapshots block the step only for the O(1) capture; the write
/// happens on the background thread (surfaced as `snapshot_write_secs`).
/// Sync mode pays the full write inline and hides nothing.
#[test]
fn async_snapshots_only_block_for_capture() {
    let Some(m) = optimus::manifest_or_skip("kill_resume::async_snapshot_accounting") else {
        return;
    };
    let ck_async = ckroot("acct-async");
    let r_async = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 8)
            .checkpoint_dir(&ck_async)
            .ckpt_every(2)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_eq!(r_async.ckpt_commits, 3, "commits at steps 2, 4, 6");
    assert!(r_async.breakdown.snapshot_secs > 0.0, "capture stall recorded");
    assert!(
        r_async.breakdown.snapshot_write_secs > 0.0,
        "hidden background write time recorded"
    );

    let ck_sync = ckroot("acct-sync");
    let r_sync = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 8)
            .checkpoint_dir(&ck_sync)
            .ckpt_every(2)
            .ckpt_async(false)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_eq!(r_sync.ckpt_commits, 3);
    assert!(r_sync.breakdown.snapshot_secs > 0.0);
    assert_eq!(
        r_sync.breakdown.snapshot_write_secs, 0.0,
        "sync mode hides nothing — the write IS the stall"
    );
    // both modes leave the same newest committed checkpoint
    let a = optimus::ckpt::SavedCheckpoint::load_latest(&ck_async).unwrap();
    let b = optimus::ckpt::SavedCheckpoint::load_latest(&ck_sync).unwrap();
    assert_eq!((a.step, b.step), (6, 6));
    let _ = std::fs::remove_dir_all(&ck_async);
    let _ = std::fs::remove_dir_all(&ck_sync);
}

/// A `--dtype bf16` run checkpoints half-width parameter shards; resume
/// validates the dtype: the matching plan continues cleanly, a `--dtype
/// f32` resume is refused with the stable `[dtype]` string (silently
/// up-converting params would shift the loss trajectory unrecorded).
#[test]
fn bf16_checkpoint_resumes_and_rejects_f32_plan() {
    let Some(m) = optimus::manifest_or_skip("kill_resume::bf16_resume_dtype_gate") else {
        return;
    };
    let ck = ckroot("bf16");
    let produced = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 5)
            .dtype(Dtype::Bf16)
            .checkpoint_dir(&ck)
            .ckpt_every(2)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert!(produced.ckpt_commits >= 2, "commits at steps 2 and 4");
    assert!(produced.ckpt_bytes > 0, "shard payload bytes recorded");
    // the resuming plan's default --dtype f32 mismatches the bf16 shards
    let e = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 8).checkpoint_dir(&ck).build().unwrap(),
    )
    .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("checkpoint resume failed [dtype]"), "{msg}");
    assert_eq!(optimus::ft::classify(&e), optimus::ft::FailureKind::Config, "{msg}");
    // the matching dtype resumes from the step-4 checkpoint
    let r = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 8)
            .dtype(Dtype::Bf16)
            .checkpoint_dir(&ck)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_eq!(r.loss.points.first().unwrap().0, 5);
    for (_, l) in &r.loss.points {
        assert!(l.is_finite());
    }
    let _ = std::fs::remove_dir_all(&ck);
}

/// Resuming a different model's checkpoint fails the preflight with the
/// stable `[model]` string, before any rank thread spawns — and the
/// launcher classifies it as non-relaunchable.
#[test]
fn resume_rejects_a_different_model_checkpoint() {
    let Some(m) = optimus::manifest_or_skip("kill_resume::resume_rejects_different_model")
    else {
        return;
    };
    let ck = ckroot("wrong-model");
    let r = coordinator::train(
        &m,
        &base(Topology::dp_only(2), 5)
            .checkpoint_dir(&ck)
            .ckpt_every(2)
            .build()
            .unwrap(),
    );
    assert!(r.is_ok());
    // rewrite the committed manifest as if another model had saved it
    let slot = ck.join("ckpt-00000004");
    let manifest_path = slot.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).unwrap();
    std::fs::write(&manifest_path, text.replace("mula-tiny/", "mula-other/")).unwrap();
    let s = base(Topology::dp_only(2), 8).checkpoint_dir(&ck).build().unwrap();
    let e = coordinator::train(&m, &s).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("checkpoint resume failed [model]"), "{msg}");
    // the preflight failure is deterministic: the launcher must surface
    // it instead of burning buffer nodes on relaunches
    assert_eq!(optimus::ft::classify(&e), optimus::ft::FailureKind::Config, "{msg}");
    let _ = std::fs::remove_dir_all(&ck);
}
