//! The nine lint passes, all running over the token/block view built by
//! [`super::lexer`].
//!
//! Migrated from the PR 7 line scanner: **check-strings**,
//! **check-coverage**, **named-spawn** (tightened: a
//! `std::thread::Builder` chain must actually call `.name(..)` before
//! `.spawn(..)`), **lock-discipline**, **metrics-class**.
//!
//! New flow-aware passes:
//!
//! * **collective-divergence** — a `Group::run`/`Group::start` call site
//!   carrying a `CollectiveOp` that executes only under a rank-dependent
//!   condition (`rank`, `slot`, `lane`, `is_leader`, `*_rank`,
//!   `*_coord`, …) deadlocks every peer parked in the same round. Flagged
//!   unless the condition's sibling arms issue the identical collective
//!   sequence, or the site carries a `// lint: rank-uniform <why>`
//!   annotation inside its enclosing block.
//! * **collective-order** — when *every* arm of a rank-dependent branch
//!   issues collectives but the kind sequences differ, ranks taking
//!   different arms disagree on program order: the runtime `[order]`
//!   auditor fires on the lucky runs and a silent hang eats the unlucky
//!   ones. One finding per branch point.
//! * **lock-order** — per-function lock-acquisition sequences across
//!   `comm/`, `ckpt/` and `serve/`; any two locks taken in both orders
//!   anywhere in that surface is the classic AB/BA deadlock loom can
//!   only find where a model exists. `let`-bound guards are treated as
//!   held to the end of their block (RAII); temporaries (no `let`, or a
//!   chain continuing past the lock) participate only as second
//!   acquisitions.
//! * **poison-path** — inside rank-thread / lane-worker spawn closures
//!   (thread name contains `rank` or `lane`), a bare
//!   `unwrap`/`expect`/`panic!` strands every peer of the dead rank
//!   unless the closure routes panics through the poison protocol
//!   (`Group::poison`/`poison_all`/`PoisonGuard`/`catch_unwind`).
//!
//! All heuristics are intraprocedural and token-shaped: conditions are
//! judged rank-dependent by identifier, collective kinds by the
//! `CollectiveOp::<Kind>` constructor at the call site, and calls into
//! helpers are not traced. The runtime auditor, watchdog and loom models
//! (DESIGN.md §12) stay the backstop for what a lint cannot see.

use super::lexer::{match_paren, Block, Kind, Node, Tok};
use super::{FileView, Violation};
use crate::ft::checks;
use std::collections::{BTreeMap, BTreeSet};

/// Every pass, by stable rule slug — also the `ft::checks` LINT registry
/// names the CLI summary emits.
pub const RULES: &[&str] = &[
    "check-strings",
    "check-coverage",
    "named-spawn",
    "lock-discipline",
    "metrics-class",
    "collective-divergence",
    "collective-order",
    "lock-order",
    "poison-path",
];

/// Identifiers that make a condition rank-dependent: a branch on any of
/// these can differ across members of one collective family.
fn rankish_ident(s: &str) -> bool {
    matches!(s, "rank" | "slot" | "lane" | "node" | "leader" | "is_leader" | "is_last" | "is_first" | "coord" | "stage")
        || s.ends_with("_rank")
        || s.ends_with("_slot")
        || s.ends_with("_lane")
        || s.ends_with("_coord")
        || s.ends_with("_stage")
}

// ---------------------------------------------------------------------
// check-strings + the check-coverage census
// ---------------------------------------------------------------------

/// Scan every string literal for `<domain> [<name>]` failure tags:
/// unknown names/domains are violations; tags seen in test code feed the
/// coverage census (`asserted`).
pub fn check_strings(
    view: &FileView<'_>,
    domains: &[&'static str],
    v: &mut Vec<Violation>,
    asserted: &mut BTreeSet<(&'static str, &'static str)>,
) {
    for (i, t) in view.lx.toks.iter().enumerate() {
        if t.kind != Kind::Str {
            continue;
        }
        let s = &t.text;
        for word in ["failed", "violated"] {
            let pat = format!("{word} [");
            let mut from = 0usize;
            while let Some(off) = s[from..].find(&pat) {
                let p = from + off;
                let after = p + pat.len();
                from = after;
                let Some(end) = s[after..].find(']') else { continue };
                let name = &s[after..after + end];
                let tag_shaped = !name.is_empty()
                    && name
                        .chars()
                        .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-');
                if !tag_shaped {
                    continue;
                }
                let line = t.line + s[..p].matches('\n').count();
                let head = &s[..p + word.len()];
                match domains.iter().find(|d| head.ends_with(**d)) {
                    Some(d) => match checks::CHECKS
                        .iter()
                        .find(|c| c.domain == *d && c.name == name)
                    {
                        Some(c) => {
                            if view.test[i] {
                                asserted.insert((c.domain, c.name));
                            }
                        }
                        None => v.push(Violation {
                            file: view.f.rel.clone(),
                            line,
                            rule: "check-strings",
                            msg: format!("`{d} [{name}]` is not registered in ft::checks::CHECKS"),
                        }),
                    },
                    None => {
                        let tail: String = {
                            let mut cs: Vec<char> = head.chars().rev().take(30).collect();
                            cs.reverse();
                            cs.into_iter().collect()
                        };
                        v.push(Violation {
                            file: view.f.rel.clone(),
                            line,
                            rule: "check-strings",
                            msg: format!(
                                "check-shaped tag `[{name}]` follows an unknown failure domain \
                                 (`...{tail}`) — route it through ft::checks"
                            ),
                        })
                    }
                }
            }
        }
    }
}

/// Coverage direction: every registered check must have been seen (as
/// its full stable literal) in at least one test. The finding anchors to
/// the check's registry row when the registry file is in the scanned
/// set.
pub fn check_coverage(
    files: &[FileView<'_>],
    asserted: &BTreeSet<(&'static str, &'static str)>,
    v: &mut Vec<Violation>,
) {
    let registry = files.iter().find(|f| f.f.rel.ends_with("ft/checks.rs"));
    for c in checks::CHECKS {
        if asserted.contains(&(c.domain, c.name)) {
            continue;
        }
        // point at the CheckId row: the name appears as a string literal
        let line = registry
            .and_then(|r| {
                r.lx.toks
                    .iter()
                    .find(|t| t.kind == Kind::Str && t.text == c.name)
                    .map(|t| t.line)
            })
            .unwrap_or(0);
        v.push(Violation {
            file: "src/ft/checks.rs".into(),
            line,
            rule: "check-coverage",
            msg: format!(
                "registered check `{} [{}]` is asserted by no test — add a test \
                 containing its full stable string",
                c.domain, c.name
            ),
        });
    }
}

// ---------------------------------------------------------------------
// named-spawn
// ---------------------------------------------------------------------

/// No bare `thread::spawn` outside tests, and — the tightened contract —
/// every `std::thread::Builder` chain that reaches `.spawn(..)` must
/// have called `.name(..)` on the way.
pub fn named_spawn(view: &FileView<'_>, v: &mut Vec<Violation>) {
    if view.f.rel == "src/comm/lsync.rs" {
        // the loom shim: loom's spawn has no named builder
        return;
    }
    let toks = &view.lx.toks;
    for i in 0..toks.len() {
        if view.test[i] {
            continue;
        }
        if toks[i].is_ident("thread")
            && punct2(toks, i + 1, ':', ':')
            && toks.get(i + 3).is_some_and(|t| t.is_ident("spawn"))
        {
            v.push(Violation {
                file: view.f.rel.clone(),
                line: toks[i].line,
                rule: "named-spawn",
                msg: "bare thread::spawn — use std::thread::Builder::new().name(..) \
                      (joinable, shows up in stall dumps) or comm::lsync::spawn_named"
                    .into(),
            });
            continue;
        }
        if !toks[i].is_ident("Builder") {
            continue;
        }
        let from_thread = i >= 3
            && punct2(toks, i - 2, ':', ':')
            && toks[i - 3].is_ident("thread");
        let to_new = punct2(toks, i + 1, ':', ':')
            && toks.get(i + 3).is_some_and(|t| t.is_ident("new"));
        if !(from_thread || to_new) {
            continue;
        }
        // walk the method chain: receiver-position method names are the
        // `.m(` at zero bracket depth before the statement ends
        let (mut has_name, mut has_spawn) = (false, false);
        let (mut pd, mut bd) = (0i64, 0i64);
        let mut j = i + 1;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('(') || t.is_punct('[') {
                pd += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                pd -= 1;
            } else if t.is_punct('{') {
                bd += 1;
            } else if t.is_punct('}') {
                bd -= 1;
                if bd < 0 {
                    break;
                }
            } else if pd == 0 && bd == 0 {
                if t.is_punct(';') {
                    break;
                }
                if t.is_punct('.') {
                    if let Some(m) = toks.get(j + 1) {
                        has_name |= m.is_ident("name");
                        has_spawn |= m.is_ident("spawn");
                    }
                }
            }
            j += 1;
        }
        if has_spawn && !has_name {
            v.push(Violation {
                file: view.f.rel.clone(),
                line: toks[i].line,
                rule: "named-spawn",
                msg: "thread::Builder chain reaches .spawn(..) without .name(..) — \
                      unnamed threads are unattributable in stall dumps and panics"
                    .into(),
            });
        }
    }
}

fn punct2(toks: &[Tok], i: usize, a: char, b: char) -> bool {
    toks.get(i).is_some_and(|t| t.is_punct(a)) && toks.get(i + 1).is_some_and(|t| t.is_punct(b))
}

// ---------------------------------------------------------------------
// lock-discipline
// ---------------------------------------------------------------------

/// `.lock().unwrap()` stays confined to `comm/` and `ckpt/` (whose
/// protocols poison deliberately); everyone else uses the
/// poison-tolerant `crate::util::lock`.
pub fn lock_discipline(view: &FileView<'_>, v: &mut Vec<Violation>) {
    if view.f.rel.starts_with("src/comm/") || view.f.rel.starts_with("src/ckpt/") {
        return;
    }
    let toks = &view.lx.toks;
    for i in 0..toks.len() {
        if view.test[i] {
            continue;
        }
        if toks[i].is_punct('.')
            && toks.get(i + 1).is_some_and(|t| t.is_ident("lock"))
            && punct2(toks, i + 2, '(', ')')
            && toks.get(i + 4).is_some_and(|t| t.is_punct('.'))
            && toks.get(i + 5).is_some_and(|t| t.is_ident("unwrap"))
        {
            v.push(Violation {
                file: view.f.rel.clone(),
                line: toks[i + 1].line,
                rule: "lock-discipline",
                msg: "`.lock().unwrap()` outside comm/ and ckpt/ — use the \
                      poison-tolerant crate::util::lock so one panicked thread \
                      doesn't cascade"
                    .into(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// metrics-class
// ---------------------------------------------------------------------

/// Every `f64` field of `StepBreakdown` documents its accounting class,
/// so `total()` can be audited against the tags.
pub fn metrics_class(view: &FileView<'_>, v: &mut Vec<Violation>) {
    let toks = &view.lx.toks;
    let Some(at) = toks
        .windows(2)
        .position(|w| w[0].is_ident("struct") && w[1].is_ident("StepBreakdown"))
    else {
        v.push(Violation {
            file: view.f.rel.clone(),
            line: 0,
            rule: "metrics-class",
            msg: "pub struct StepBreakdown not found — if it moved, update \
                  analysis::passes::metrics_class"
                .into(),
        });
        return;
    };
    let Some(open) = (at..toks.len()).find(|&j| toks[j].is_punct('{')) else { return };
    let mut depth = 0i64;
    let mut anchor = toks[open].line;
    let mut j = open;
    while j < toks.len() {
        if toks[j].is_punct('{') {
            depth += 1;
        } else if toks[j].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if depth == 1
            && toks[j].is_ident("pub")
            && toks.get(j + 1).is_some_and(|t| t.kind == Kind::Ident)
            && toks.get(j + 2).is_some_and(|t| t.is_punct(':'))
            && toks.get(j + 3).is_some_and(|t| t.is_ident("f64"))
        {
            let field = &toks[j + 1];
            let classified = view.lx.comments.iter().any(|c| {
                c.line > anchor
                    && c.line < field.line
                    && (c.text.contains("class: additive")
                        || c.text.contains("class: concurrent")
                        || c.text.contains("class: contained"))
            });
            if !classified {
                v.push(Violation {
                    file: view.f.rel.clone(),
                    line: field.line,
                    rule: "metrics-class",
                    msg: format!(
                        "StepBreakdown field `{}: f64` lacks a `class: \
                         additive|concurrent|contained` doc tag",
                        field.text
                    ),
                });
            }
            anchor = field.line;
            j += 4;
            continue;
        }
        j += 1;
    }
}

// ---------------------------------------------------------------------
// collective-divergence + collective-order
// ---------------------------------------------------------------------

/// One collective call site: the `CollectiveOp` constructor kind and the
/// line of the `.run(`/`.start(` call.
#[derive(Clone, Debug)]
struct Site {
    kind: String,
    line: usize,
}

/// Is token `i` the `.` of a `.run(`/`.start(` call whose arguments
/// construct a `CollectiveOp`? Returns the site and the index past the
/// closing paren.
fn collective_at(toks: &[Tok], i: usize) -> Option<(Site, usize)> {
    if !toks[i].is_punct('.') {
        return None;
    }
    let m = toks.get(i + 1)?;
    if !(m.is_ident("run") || m.is_ident("start")) {
        return None;
    }
    if !toks.get(i + 2)?.is_punct('(') {
        return None;
    }
    let close = match_paren(toks, i + 2);
    let mut kind = None;
    for k in i + 3..close.min(toks.len()) {
        if toks[k].is_ident("CollectiveOp") && punct2(toks, k + 1, ':', ':') {
            kind = toks.get(k + 3).map(|t| t.text.clone());
            break;
        }
    }
    kind.map(|kind| (Site { kind, line: m.line }, close + 1))
}

/// Split a `match` body into per-arm node regions: pattern tokens up to
/// `=>`, then either a block arm or an expression arm running to the
/// `,` at arm depth.
fn match_arms<'b>(view: &FileView<'_>, body: &'b [Node]) -> Vec<Vec<&'b Node>> {
    let toks = &view.lx.toks;
    let mut arms = Vec::new();
    let mut i = 0usize;
    while i < body.len() {
        // skip the pattern: to `=>` at paren depth 0
        let mut pd = 0i64;
        let mut found = false;
        while i < body.len() {
            if let Node::Tok(t) = &body[i] {
                if toks[*t].is_punct('(') || toks[*t].is_punct('[') {
                    pd += 1;
                } else if toks[*t].is_punct(')') || toks[*t].is_punct(']') {
                    pd -= 1;
                } else if pd == 0
                    && toks[*t].is_punct('=')
                    && toks.get(t + 1).is_some_and(|n| n.is_punct('>'))
                {
                    i += 1; // the '>' token node
                    found = true;
                }
            }
            i += 1;
            if found {
                break;
            }
        }
        if !found {
            break;
        }
        // the arm value: nodes to the `,` at depth 0 (blocks included)
        let mut arm: Vec<&'b Node> = Vec::new();
        let mut pd = 0i64;
        while i < body.len() {
            match &body[i] {
                Node::Tok(t) => {
                    if toks[*t].is_punct('(') || toks[*t].is_punct('[') {
                        pd += 1;
                    } else if toks[*t].is_punct(')') || toks[*t].is_punct(']') {
                        pd -= 1;
                    } else if pd == 0 && toks[*t].is_punct(',') {
                        i += 1;
                        break;
                    }
                    arm.push(&body[i]);
                }
                Node::Block(_) => {
                    arm.push(&body[i]);
                    // a block arm may omit the trailing comma
                    if pd == 0 {
                        if let Some(Node::Tok(t)) = body.get(i + 1) {
                            if toks[*t].is_punct(',') {
                                i += 1;
                            }
                        }
                        i += 1;
                        break;
                    }
                }
            }
            i += 1;
        }
        arms.push(arm);
    }
    arms
}

/// Collect the collective sequence of a region: `uncond` sites always
/// execute when the region does (loops assumed uniform-trip); `cond`
/// sites sit under a further branch inside the region, so they may or
/// may not execute.
fn collect_seq(view: &FileView<'_>, nodes: &[&Node], uncond: &mut Vec<Site>, cond: &mut Vec<Site>) {
    let toks = &view.lx.toks;
    let mut i = 0usize;
    while i < nodes.len() {
        match nodes[i] {
            Node::Block(b) => {
                let inner: Vec<&Node> = b.nodes.iter().collect();
                collect_seq(view, &inner, uncond, cond);
                i += 1;
            }
            Node::Tok(t) => {
                let t = *t;
                if (toks[t].is_ident("if") || toks[t].is_ident("match")) && !view.test[t] {
                    if let Some(br) = parse_branch_refs(view, nodes, i) {
                        for arm in &br.arms {
                            let mut u = Vec::new();
                            let mut c = Vec::new();
                            collect_seq(view, arm, &mut u, &mut c);
                            cond.extend(u);
                            cond.extend(c);
                        }
                        cond.extend(br.cond_sites.iter().cloned());
                        i = br.next;
                        continue;
                    }
                }
                if !view.test[t] {
                    if let Some((s, _)) = collective_at(toks, t) {
                        uncond.push(s);
                    }
                }
                i += 1;
            }
        }
    }
}

/// One parsed branch point — an `if`/`else if`/`else` chain or a
/// `match` — over a `&[&Node]` region (arms are slices of refs).
struct BranchRefs<'b> {
    rankish: bool,
    line: usize,
    cond: String,
    arms: Vec<Vec<&'b Node>>,
    open_ended: bool,
    next: usize,
    cond_sites: Vec<Site>,
}

/// Parse the branch construct starting at `nodes[at]` (an `if` or
/// `match` token). Returns `None` when the shape is unrecognizable.
fn parse_branch_refs<'b>(
    view: &FileView<'_>,
    nodes: &[&'b Node],
    at: usize,
) -> Option<BranchRefs<'b>> {
    let toks = &view.lx.toks;
    let first = match nodes.get(at) {
        Some(Node::Tok(t)) => *t,
        _ => return None,
    };
    let line = toks[first].line;
    let is_match = toks[first].is_ident("match");
    let mut rankish = false;
    let mut cond = String::new();
    let mut cond_sites = Vec::new();
    let mut arms: Vec<Vec<&'b Node>> = Vec::new();
    let mut i = at;

    let mut scan_cond = |i: &mut usize, rankish: &mut bool, cond: &mut String| -> Option<usize> {
        let mut pd = 0i64;
        let mut in_let_pattern = false;
        let mut seen_any = false;
        *i += 1;
        while *i < nodes.len() {
            match nodes[*i] {
                Node::Block(_) if pd == 0 => return Some(*i),
                Node::Block(_) => {}
                Node::Tok(t) => {
                    let t = *t;
                    if !seen_any && toks[t].is_ident("let") {
                        in_let_pattern = true;
                    }
                    seen_any = true;
                    if toks[t].is_punct('(') || toks[t].is_punct('[') {
                        pd += 1;
                    } else if toks[t].is_punct(')') || toks[t].is_punct(']') {
                        pd -= 1;
                    } else if in_let_pattern
                        && pd == 0
                        && toks[t].is_punct('=')
                        && !toks.get(t + 1).is_some_and(|n| n.is_punct('='))
                        && !punct2(toks, t.saturating_sub(1), '=', '=')
                    {
                        in_let_pattern = false;
                    } else if !in_let_pattern && toks[t].kind == Kind::Ident {
                        if rankish_ident(&toks[t].text) {
                            *rankish = true;
                        }
                        if cond.len() < 48 {
                            if !cond.is_empty() {
                                cond.push(' ');
                            }
                            cond.push_str(&toks[t].text);
                        }
                    }
                    if let Some((s, _)) = collective_at(toks, t) {
                        cond_sites.push(s);
                    }
                }
            }
            *i += 1;
        }
        None
    };

    if is_match {
        let body = scan_cond(&mut i, &mut rankish, &mut cond)?;
        let Node::Block(b) = nodes[body] else { return None };
        arms = match_arms(view, &b.nodes);
        return Some(BranchRefs { rankish, line, cond, arms, open_ended: false, next: body + 1, cond_sites });
    }
    let mut open_ended = true;
    loop {
        let arm_at = scan_cond(&mut i, &mut rankish, &mut cond)?;
        let Node::Block(b) = nodes[arm_at] else { return None };
        arms.push(b.nodes.iter().collect());
        i = arm_at + 1;
        let next_is_else = matches!(nodes.get(i), Some(Node::Tok(t)) if toks[*t].is_ident("else"));
        if !next_is_else {
            break;
        }
        i += 1;
        match nodes.get(i) {
            Some(Node::Tok(t)) if toks[*t].is_ident("if") => continue,
            Some(Node::Block(b)) => {
                arms.push(b.nodes.iter().collect());
                open_ended = false;
                i += 1;
                break;
            }
            _ => break,
        }
    }
    Some(BranchRefs { rankish, line, cond, arms, open_ended, next: i, cond_sites })
}

/// The divergence/order walker over one file.
pub fn collective_flow(view: &FileView<'_>, v: &mut Vec<Violation>) {
    let region: Vec<&Node> = view.root.nodes.iter().collect();
    flow_region(view, &region, v);
}

fn flow_region(view: &FileView<'_>, nodes: &[&Node], v: &mut Vec<Violation>) {
    let toks = &view.lx.toks;
    let mut i = 0usize;
    while i < nodes.len() {
        match nodes[i] {
            Node::Block(b) => {
                let inner: Vec<&Node> = b.nodes.iter().collect();
                flow_region(view, &inner, v);
                i += 1;
            }
            Node::Tok(t) => {
                let t = *t;
                if (toks[t].is_ident("if") || toks[t].is_ident("match")) && !view.test[t] {
                    if let Some(br) = parse_branch_refs(view, nodes, i) {
                        analyze_branch(view, &br, nodes, v);
                        for arm in &br.arms {
                            flow_region(view, arm, v);
                        }
                        i = br.next;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
}

/// Does an arm contain a top-level `return` (directly, not under a
/// nested block)? Gates early-return promotion.
fn arm_returns(view: &FileView<'_>, arm: &[&Node]) -> bool {
    arm.iter().any(|n| matches!(n, Node::Tok(t) if view.lx.toks[*t].is_ident("return")))
}

fn analyze_branch(
    view: &FileView<'_>,
    br: &BranchRefs<'_>,
    nodes: &[&Node],
    v: &mut Vec<Violation>,
) {
    if !br.rankish {
        return;
    }
    let mut seqs: Vec<(Vec<Site>, Vec<Site>)> = Vec::new();
    for arm in &br.arms {
        let mut u = Vec::new();
        let mut c = Vec::new();
        collect_seq(view, arm, &mut u, &mut c);
        seqs.push((u, c));
    }
    if br.open_ended {
        // `if rank-dep { … return }` guards: the code after the chain is
        // the implicit else arm. Without returns it's an empty arm.
        if !br.arms.is_empty() && br.arms.iter().all(|a| arm_returns(view, a)) {
            let rest: Vec<&Node> = nodes[br.next..].to_vec();
            let mut u = Vec::new();
            let mut c = Vec::new();
            collect_seq(view, &rest, &mut u, &mut c);
            seqs.push((u, c));
        } else {
            seqs.push((Vec::new(), Vec::new()));
        }
    }
    let total: usize = seqs.iter().map(|(u, c)| u.len() + c.len()).sum::<usize>()
        + br.cond_sites.len();
    if total == 0 {
        return;
    }
    let kinds = |u: &[Site]| u.iter().map(|s| s.kind.clone()).collect::<Vec<_>>();
    let all_equal = seqs.iter().all(|(_, c)| c.is_empty())
        && br.cond_sites.is_empty()
        && seqs.windows(2).all(|w| kinds(&w[0].0) == kinds(&w[1].0));
    if all_equal {
        return;
    }
    let order_case = !seqs.is_empty()
        && seqs.iter().all(|(u, c)| !u.is_empty() && c.is_empty())
        && br.cond_sites.is_empty();
    if order_case {
        if !suppressed(view, "rank-uniform", br.line) {
            let shown: Vec<String> =
                seqs.iter().map(|(u, _)| kinds(u).join(",")).collect();
            v.push(Violation {
                file: view.f.rel.clone(),
                line: br.line,
                rule: "collective-order",
                msg: format!(
                    "arms of the rank-dependent branch on `{}` issue different \
                     collective sequences ({}) — every rank must see the identical \
                     program order, or the family deadlocks/fails `[order]` at run time",
                    br.cond,
                    shown.join(" vs ")
                ),
            });
        }
        return;
    }
    for site in seqs
        .iter()
        .flat_map(|(u, c)| u.iter().chain(c.iter()))
        .chain(br.cond_sites.iter())
    {
        if suppressed(view, "rank-uniform", site.line) {
            continue;
        }
        v.push(Violation {
            file: view.f.rel.clone(),
            line: site.line,
            rule: "collective-divergence",
            msg: format!(
                "collective {} is reachable only under the rank-dependent \
                 condition `{}` — a subset of the group entering a round deadlocks \
                 the rest; prove uniformity and annotate \
                 `// lint: rank-uniform <why>`, or hoist the call",
                site.kind, br.cond
            ),
        });
    }
}

/// Does an enabled annotation of `rule` cover `line`? Coverage is the
/// annotation's innermost enclosing block — put the annotation inside
/// the guarded arm, next to the call it vouches for.
fn suppressed(view: &FileView<'_>, rule: &str, line: usize) -> bool {
    view.lx
        .annos
        .iter()
        .filter(|a| a.rule == rule && !a.reason.is_empty())
        .any(|a| {
            let span = innermost_span(&view.root, a.line);
            line >= span.0 && line <= span.1
        })
}

fn innermost_span(root: &Block, line: usize) -> (usize, usize) {
    let mut best = (root.open_line, root.close_line.max(root.open_line));
    fn rec(b: &Block, line: usize, best: &mut (usize, usize)) {
        if line < b.open_line || line > b.close_line {
            return;
        }
        if b.close_line - b.open_line <= best.1 - best.0 {
            *best = (b.open_line, b.close_line);
        }
        for n in &b.nodes {
            if let Node::Block(c) = n {
                rec(c, line, best);
            }
        }
    }
    rec(root, line, &mut best);
    best
}

// ---------------------------------------------------------------------
// lock-order
// ---------------------------------------------------------------------

/// First witness of an ordered pair of lock acquisitions.
#[derive(Clone, Debug)]
pub struct PairWitness {
    pub file: String,
    pub line: usize,
    pub func: String,
}

pub type PairTable = BTreeMap<(String, String), PairWitness>;

/// Collect per-function ordered lock pairs for one file (only called for
/// `comm/`, `ckpt/`, `serve/`).
pub fn lock_order_collect(view: &FileView<'_>, table: &mut PairTable) {
    let region: Vec<&Node> = view.root.nodes.iter().collect();
    each_fn(view, &region, &mut |name, body| {
        let inner: Vec<&Node> = body.nodes.iter().collect();
        let mut held: Vec<String> = Vec::new();
        walk_locks(view, &inner, &mut held, name, table);
    });
}

/// Find `fn NAME … { … }` items in a region, recursing into every block
/// (impls, modules, nested fns).
fn each_fn(view: &FileView<'_>, nodes: &[&Node], cb: &mut impl FnMut(&str, &Block)) {
    let toks = &view.lx.toks;
    let mut i = 0usize;
    while i < nodes.len() {
        match nodes[i] {
            Node::Block(b) => {
                let inner: Vec<&Node> = b.nodes.iter().collect();
                each_fn(view, &inner, cb);
                i += 1;
            }
            Node::Tok(t) => {
                let t = *t;
                if toks[t].is_ident("fn")
                    && !view.test[t]
                    && matches!(nodes.get(i + 1), Some(Node::Tok(n)) if toks[*n].kind == Kind::Ident)
                {
                    let name = match nodes[i + 1] {
                        Node::Tok(n) => view.lx.toks[*n].text.clone(),
                        _ => unreachable!("checked ident"),
                    };
                    // body = first sibling block before a `;`
                    let mut j = i + 2;
                    while j < nodes.len() {
                        match nodes[j] {
                            Node::Tok(s) if toks[*s].is_punct(';') => break,
                            Node::Block(b) => {
                                cb(&name, b);
                                break;
                            }
                            _ => j += 1,
                        }
                    }
                    i = j + 1;
                    continue;
                }
                i += 1;
            }
        }
    }
}

struct LockAcq {
    name: String,
    line: usize,
    held: bool,
}

fn walk_locks(
    view: &FileView<'_>,
    nodes: &[&Node],
    held: &mut Vec<String>,
    func: &str,
    table: &mut PairTable,
) {
    let base = held.len();
    let toks = &view.lx.toks;
    for n in nodes {
        match n {
            Node::Block(b) => {
                let inner: Vec<&Node> = b.nodes.iter().collect();
                walk_locks(view, &inner, held, func, table);
            }
            Node::Tok(t) => {
                if view.test[*t] {
                    continue;
                }
                if let Some(acq) = lock_acq_at(toks, *t) {
                    for h in held.iter() {
                        if *h != acq.name {
                            table
                                .entry((h.clone(), acq.name.clone()))
                                .or_insert_with(|| PairWitness {
                                    file: view.f.rel.clone(),
                                    line: acq.line,
                                    func: func.to_string(),
                                });
                        }
                    }
                    if acq.held {
                        held.push(acq.name);
                    }
                }
            }
        }
    }
    held.truncate(base);
}

/// Recognize a lock acquisition at token `t`: `<chain>.lock()` (std
/// mutex) or `lock(&<chain>)` / `util::lock(&<chain>)` (the
/// poison-tolerant wrapper). The lock's name is the nearest field/var
/// identifier; `let`-bound-and-statement-final acquisitions are held.
fn lock_acq_at(toks: &[Tok], t: usize) -> Option<LockAcq> {
    // `<chain> . lock ( )`
    if toks[t].is_punct('.')
        && toks.get(t + 1).is_some_and(|x| x.is_ident("lock"))
        && punct2(toks, t + 2, '(', ')')
    {
        let name = chain_name_before(toks, t)?;
        let mut j = t + 4;
        if toks.get(j).is_some_and(|x| x.is_punct('.'))
            && toks.get(j + 1).is_some_and(|x| x.is_ident("unwrap"))
            && punct2(toks, j + 2, '(', ')')
        {
            j += 4;
        }
        let stmt_final = toks.get(j).is_some_and(|x| x.is_punct(';'));
        return Some(LockAcq {
            name,
            line: toks[t + 1].line,
            held: stmt_final && stmt_starts_with_let(toks, t),
        });
    }
    // `lock ( & <chain> )` — the util::lock wrapper (possibly
    // path-qualified); exclude method position `.lock(`
    if toks[t].is_ident("lock")
        && toks.get(t + 1).is_some_and(|x| x.is_punct('('))
        && !(t > 0 && toks[t - 1].is_punct('.'))
    {
        let close = match_paren(toks, t + 1);
        if close >= toks.len() {
            return None;
        }
        let name = (t + 2..close)
            .rev()
            .find(|&k| toks[k].kind == Kind::Ident)
            .map(|k| toks[k].text.clone())?;
        let stmt_final = toks.get(close + 1).is_some_and(|x| x.is_punct(';'));
        return Some(LockAcq {
            name,
            line: toks[t].line,
            held: stmt_final && stmt_starts_with_let(toks, t),
        });
    }
    None
}

/// Walk back over `a.b[c].d` to the chain's base-most *field* ident —
/// the token just before the final `.`, skipping `[…]` index groups.
fn chain_name_before(toks: &[Tok], dot: usize) -> Option<String> {
    let mut k = dot;
    loop {
        if k == 0 {
            return None;
        }
        k -= 1;
        if toks[k].is_punct(']') {
            let mut depth = 1usize;
            while k > 0 && depth > 0 {
                k -= 1;
                if toks[k].is_punct(']') {
                    depth += 1;
                } else if toks[k].is_punct('[') {
                    depth -= 1;
                }
            }
            continue;
        }
        if toks[k].is_punct(')') {
            // method-call result, e.g. `self.q().lock()`: name by method
            let mut depth = 1usize;
            while k > 0 && depth > 0 {
                k -= 1;
                if toks[k].is_punct(')') {
                    depth += 1;
                } else if toks[k].is_punct('(') {
                    depth -= 1;
                }
            }
            continue;
        }
        if toks[k].kind == Kind::Ident {
            return Some(toks[k].text.clone());
        }
        return None;
    }
}

/// Does the statement containing token `t` begin with `let`? Backscan to
/// the nearest statement delimiter.
fn stmt_starts_with_let(toks: &[Tok], t: usize) -> bool {
    let mut k = t;
    while k > 0 {
        k -= 1;
        let x = &toks[k];
        if x.is_punct(';') || x.is_punct('{') || x.is_punct('}') {
            return toks.get(k + 1).is_some_and(|n| n.is_ident("let"));
        }
    }
    toks.first().is_some_and(|n| n.is_ident("let"))
}

/// Cross-file finalization: any pair present in both orders is an AB/BA
/// inversion.
pub fn lock_order_finalize(table: &PairTable, v: &mut Vec<Violation>) {
    for ((a, b), w_ab) in table {
        if a >= b {
            continue;
        }
        let Some(w_ba) = table.get(&(b.clone(), a.clone())) else { continue };
        v.push(Violation {
            file: w_ba.file.clone(),
            line: w_ba.line,
            rule: "lock-order",
            msg: format!(
                "locks `{a}` and `{b}` are acquired in both orders: \
                 {}:{} ({}) takes `{a}` then `{b}`, but {}:{} ({}) takes \
                 `{b}` then `{a}` — two threads interleaving these deadlock; \
                 pick one global order",
                w_ab.file, w_ab.line, w_ab.func, w_ba.file, w_ba.line, w_ba.func
            ),
        });
    }
}

// ---------------------------------------------------------------------
// poison-path
// ---------------------------------------------------------------------

/// In rank-thread / lane-worker spawn closures (thread name mentions
/// `rank` or `lane`), `unwrap`/`expect`/`panic!` must sit behind the
/// poison protocol so a panic can never strand the peers parked in the
/// same collective round. `CommRuntime::submit` closures are exempt by
/// contract (catch_unwind + re-throw at `wait()`).
pub fn poison_path(view: &FileView<'_>, v: &mut Vec<Violation>) {
    let toks = &view.lx.toks;
    for i in 0..toks.len() {
        if view.test[i] {
            continue;
        }
        let (arg_open, name_region) = if toks[i].is_punct('.')
            && toks.get(i + 1).is_some_and(|t| t.is_ident("spawn"))
            && toks.get(i + 2).is_some_and(|t| t.is_punct('('))
        {
            (i + 2, builder_name_region(toks, i))
        } else if toks[i].is_ident("spawn_named")
            && toks.get(i + 1).is_some_and(|t| t.is_punct('('))
        {
            (i + 1, first_arg_region(toks, i + 1))
        } else {
            continue;
        };
        let Some((ns, ne)) = name_region else { continue };
        let scoped = (ns..ne).any(|k| {
            (toks[k].kind == Kind::Str || toks[k].kind == Kind::Ident)
                && (toks[k].text.contains("rank") || toks[k].text.contains("lane"))
        });
        if !scoped {
            continue;
        }
        let close = match_paren(toks, arg_open);
        let routed = (arg_open + 1..close.min(toks.len())).any(|k| {
            toks[k].kind == Kind::Ident
                && (toks[k].text.to_ascii_lowercase().contains("poison")
                    || toks[k].text == "catch_unwind")
        });
        if routed {
            continue;
        }
        for k in arg_open + 1..close.min(toks.len()) {
            let offender = (toks[k].is_ident("unwrap") || toks[k].is_ident("expect"))
                && k > 0
                && toks[k - 1].is_punct('.')
                || (toks[k].is_ident("panic")
                    && toks.get(k + 1).is_some_and(|t| t.is_punct('!')));
            if offender && !suppressed(view, "poison-path", toks[k].line) {
                v.push(Violation {
                    file: view.f.rel.clone(),
                    line: toks[k].line,
                    rule: "poison-path",
                    msg: format!(
                        "`{}` inside a rank/lane worker closure — a panic here \
                         strands every peer in the current round; route it \
                         through Group::poison / a PoisonGuard (or annotate \
                         `// lint: poison-path <why>`)",
                        toks[k].text
                    ),
                });
            }
        }
    }
}

/// For a `.spawn(` at `dot`, find the `.name(..)` argument region of the
/// same builder chain (backscan within the statement).
fn builder_name_region(toks: &[Tok], dot: usize) -> Option<(usize, usize)> {
    let mut k = dot;
    while k > 0 {
        k -= 1;
        let t = &toks[k];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            return None;
        }
        if t.is_ident("name")
            && k > 0
            && toks[k - 1].is_punct('.')
            && toks.get(k + 1).is_some_and(|x| x.is_punct('('))
        {
            return Some((k + 2, match_paren(toks, k + 1)));
        }
    }
    None
}

/// First argument of the call whose `(` sits at `open`: tokens up to the
/// `,` at depth 1 (or the close paren).
fn first_arg_region(toks: &[Tok], open: usize) -> Option<(usize, usize)> {
    let close = match_paren(toks, open);
    let mut depth = 0i64;
    for k in open..close.min(toks.len()) {
        if toks[k].is_punct('(') || toks[k].is_punct('[') {
            depth += 1;
        } else if toks[k].is_punct(')') || toks[k].is_punct(']') {
            depth -= 1;
        } else if depth == 1 && toks[k].is_punct(',') {
            return Some((open + 1, k));
        }
    }
    Some((open + 1, close.min(toks.len())))
}
