//! `optimus` — CLI for the Optimus-RS training stack.
//!
//! Subcommands:
//!   models                      list model configs (paper Table 1 + analogs)
//!   preprocess --out DIR        run tokenize->shuffle->shard on the corpus
//!   train --model M [--dp N --ep N --pp N --steps N --warmup N --lr F]
//!         [--mode so|epso] [--ep-comm allgather|all2all]
//!         [--schedule gpipe|1f1b] [--micro N] [--fur] [--pool N]
//!         [--seed N] [--data DIR] [--log-every N]
//!         [--data-seed N] [--no-prefetch] [--epochs N]
//!         [--dtype f32|bf16] (bf16: half-width params/wires/checkpoint
//!         payloads with f32 master weights in the optimizer)
//!         [--node-size N] (tiles per node: N > 1 runs allreduce /
//!         reduce-scatter / allgather as the three-phase hierarchy —
//!         intra-node, leaders inter-node, intra-node broadcast — and
//!         splits the traffic counters intra vs inter; N must divide
//!         dp*ep*pp, 1 is the flat single-level default)
//!         [--overlap] [--overlap-chunk N]
//!         [--ckpt-dir DIR --ckpt-every N --ckpt-sync --ckpt-keep K]
//!   eval --model M              run the synthetic benchmark suite
//!   serve --ckpt-dir DIR [--model M --dp N --ep N] [--static]
//!         [--requests N --rate RPS --seed N] [--prompt-min N --prompt-max N]
//!         [--gen-min N --gen-max N --queue-depth N]
//!         [--kv-pages N --kv-page-size N] [--pool N] [--json FILE]
//!         expert-parallel inference from a training checkpoint: continuous
//!         batching (or --static for the baseline), paged KV cache, seeded
//!         open-loop Poisson traffic; exits non-zero if any request of the
//!         bounded run is lost or any KV page leaks
//!   plans --world N [--model M] enumerate dp×ep×pp placements of a world
//!         [--steps N --data DIR] (with --model: instances/tokens per
//!         step per placement; with --data too: epochs the run consumes)
//!         [--dtype f32|bf16] (per-placement resident bytes/param)
//!   ckpt inspect DIR            print a checkpoint dir's manifest
//!                               (step, plan, shards, checksums, validity)
//!   scaling [--fur]             Aurora-model Fig 4b sweep
//!   predict BENCH.json          run the cluster analytic model against a
//!         measured perf-gate bench file (BENCH_PR8.json or the committed
//!         ci/bench_baseline.json) and report per-term prediction error
//!         [--model M --fur]; absent/zero bench values are record-only
//!   lint [--root DIR]           repo invariant lint: nine token-structured
//!         passes over rust/src + rust/tests — check-string registry and
//!         coverage, named-thread, lock-discipline, metrics classification,
//!         collective divergence/order, lock-order, poison-path
//!         [--json FILE --sarif FILE for machine-readable findings]
//!
//! `--ckpt-dir` enables sharded async checkpointing AND auto-resume: if
//! the directory already holds a committed checkpoint of the same model,
//! training continues from it — resharding onto the requested dp×ep×pp
//! if the topology changed.
//!
//! Unknown flags are rejected with a "did you mean" suggestion — a typo'd
//! `--stpes 500` fails loudly instead of silently training the default 50
//! steps.

use anyhow::anyhow;
use optimus::cluster::{
    self, hier_inter_traffic_ratio, scaling_efficiency, Aurora, ParallelPlan,
};
use optimus::config::models::{MulaSpec, MULA_220B, PAPER_MODELS};
use optimus::config::Manifest;
use optimus::coordinator::pipeline::Schedule;
use optimus::coordinator::{self, ep::EpComm, JobSpec, ParallelismPlan};
use optimus::data::{corpus, preprocess};
use optimus::eval;
use optimus::optim::ShardingMode;
use optimus::comm::Topology;
use optimus::runtime::{Dtype, Engine};
use optimus::serve::{BatchMode, ServeConfig, TrafficConfig};
use optimus::util::cli::Args;

const USAGE: &str = "usage: optimus <models|preprocess|train|eval|serve|plans|ckpt|scaling|predict|lint> [flags]\n\
                     see rust/src/main.rs header for flags";

const TRAIN_FLAGS: &[&str] = &[
    "model", "data", "dp", "ep", "pp", "node-size", "steps", "warmup", "lr", "mode",
    "ep-comm", "schedule", "micro", "fur", "pool", "seed", "log-every", "overlap",
    "overlap-chunk", "ckpt-dir", "ckpt-every", "ckpt-sync", "ckpt-keep", "data-seed",
    "no-prefetch", "epochs", "dtype",
];
const CKPT_FLAGS: &[&str] = &[];
const PREPROCESS_FLAGS: &[&str] =
    &["out", "seed", "files", "docs", "context", "shuffle-seed", "per-shard"];
const EVAL_FLAGS: &[&str] = &["model", "seed", "cases"];
const SERVE_FLAGS: &[&str] = &[
    "model", "ckpt-dir", "dp", "ep", "static", "requests", "rate", "seed", "prompt-min",
    "prompt-max", "gen-min", "gen-max", "queue-depth", "kv-pages", "kv-page-size", "pool",
    "json",
];
const PLANS_FLAGS: &[&str] = &["world", "model", "steps", "data", "dtype"];
const SCALING_FLAGS: &[&str] = &["fur", "model"];
const PREDICT_FLAGS: &[&str] = &["model", "fur"];
const LINT_FLAGS: &[&str] = &["root", "json", "sarif"];

fn main() -> optimus::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("models") => models(),
        Some("preprocess") => do_preprocess(&args),
        Some("train") => do_train(&args),
        Some("eval") => do_eval(&args),
        Some("serve") => do_serve(&args),
        Some("plans") => do_plans(&args),
        Some("ckpt") => do_ckpt(&args),
        Some("scaling") => do_scaling(&args),
        Some("predict") => do_predict(&args),
        Some("lint") => do_lint(&args),
        _ => {
            eprintln!("{USAGE}");
            Ok(())
        }
    }
}

fn check(args: &Args, allowed: &[&str]) -> optimus::Result<()> {
    args.expect_flags(allowed)
        .map_err(|m| anyhow!("{m}\n{USAGE}"))
}

fn models() -> optimus::Result<()> {
    println!("paper configs (Table 1, projection-only):");
    for m in PAPER_MODELS {
        println!(
            "  {:<16} layers {:<3} hidden {:<5} experts {:<4} top-{} — {:.1}B total / {:.1}B active",
            m.name, m.n_layers, m.hidden, m.n_experts, m.top_k,
            m.param_count() as f64 / 1e9,
            m.active_param_count() as f64 / 1e9
        );
    }
    let man = Manifest::load(&optimus::artifacts_dir())?;
    println!("\nrunnable analogs (artifacts built):");
    for (name, mm) in &man.configs {
        println!(
            "  {:<16} {:>8.2}M params, {} artifacts, pp={:?} ep={:?}",
            name,
            mm.param_count as f64 / 1e6,
            mm.artifacts.len(),
            mm.pp_degrees,
            mm.ep_degrees
        );
    }
    Ok(())
}

fn default_data(args: &Args, context: usize) -> optimus::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        args.str_or("data", &format!("{}/optimus-cli-data-{context}",
            std::env::temp_dir().display())));
    if !dir.exists() {
        let st = preprocess::preprocess(
            &corpus::data_files(42, 8, 64), context, 7, &dir, 2048)?;
        println!("preprocessed {} instances into {} shards", st.n_instances, st.n_shards);
    }
    Ok(dir)
}

fn do_preprocess(args: &Args) -> optimus::Result<()> {
    check(args, PREPROCESS_FLAGS)?;
    let out = std::path::PathBuf::from(args.str_or("out", "data/shards"));
    let files = corpus::data_files(
        args.usize_or("seed", 42) as u64,
        args.usize_or("files", 8),
        args.usize_or("docs", 64),
    );
    let st = preprocess::preprocess(
        &files,
        args.usize_or("context", 192),
        args.usize_or("shuffle-seed", 7) as u64,
        &out,
        args.usize_or("per-shard", 2048),
    )?;
    println!("{st:?}");
    Ok(())
}

fn do_train(args: &Args) -> optimus::Result<()> {
    check(args, TRAIN_FLAGS)?;
    let model = args.str_or("model", "mula-tiny");
    let man = Manifest::load(&optimus::artifacts_dir())?;
    let mm = man.config(&model)?;
    let data = default_data(args, mm.hyper.seq + 1)?;
    let steps = args.usize_or("steps", 50);
    let lr = args.f64_or("lr", 2e-3);

    let mut b = JobSpec::new(&model)
        .data_dir(data)
        .topology(
            args.usize_or("dp", 2),
            args.usize_or("ep", 1),
            args.usize_or("pp", 1),
        )
        // --node-size N > 1: hierarchical collectives (intra-node →
        // leaders → intra-node) with intra/inter traffic split
        .node_size(args.usize_or("node-size", 1))
        .steps(steps)
        .warmup_steps(args.usize_or("warmup", steps / 10))
        .peak_lr(lr)
        .min_lr(lr / 10.0)
        .seed(args.usize_or("seed", 1234) as u64)
        // deterministic shuffled streaming: the data order is a pure
        // function of --data-seed (blockwise reshuffle every epoch)
        .data_seed(args.usize_or("data-seed", 7) as u64)
        .data_prefetch(!args.bool_or("no-prefetch", false))
        .data_epochs(args.usize_or("epochs", 0))
        .fur(args.bool_or("fur", false))
        // --dtype bf16: half-width params/activations/wires/checkpoint
        // payloads; the optimizer keeps f32 master weights + moments
        .dtype(Dtype::parse(&args.str_or("dtype", "f32"))?)
        .micro_batches(args.usize_or("micro", 2))
        .engine_pool(args.usize_or("pool", 2))
        // --overlap: pipelined sharded-optimizer step over the async comm
        // runtime (bit-identical to serial; faster on multi-core hosts)
        .overlap(args.bool_or("overlap", false))
        .overlap_chunk(args.usize_or(
            "overlap-chunk",
            optimus::coordinator::DEFAULT_OVERLAP_CHUNK,
        ));
    if let Some(mode) = args.get("mode") {
        match mode {
            "so" => b = b.sharding(ShardingMode::So),
            // `--mode epso` was the old CLI default for every topology;
            // at ep=1 EPSO degrades to SO (numerically identical), so
            // keep that invocation working instead of hard-erroring
            "epso" if args.usize_or("ep", 1) > 1 => b = b.sharding(ShardingMode::Epso),
            "epso" => eprintln!(
                "note: EPSO needs ep > 1; this ep=1 run uses SO (numerically identical)"
            ),
            other => return Err(anyhow!("--mode wants so|epso, got `{other}`")),
        }
    }
    if let Some(c) = args.get("ep-comm") {
        b = b.ep_comm(
            EpComm::parse(c).ok_or_else(|| anyhow!("--ep-comm wants allgather|all2all, got `{c}`"))?,
        );
    }
    if let Some(s) = args.get("schedule") {
        b = b.schedule(
            Schedule::parse(s).ok_or_else(|| anyhow!("--schedule wants gpipe|1f1b, got `{s}`"))?,
        );
    }
    if let Some(dir) = args.get("ckpt-dir") {
        // sharded async checkpointing + auto-resume (paper §4)
        b = b
            .checkpoint_dir(dir)
            .ckpt_every(args.usize_or("ckpt-every", 10))
            .ckpt_async(!args.bool_or("ckpt-sync", false))
            .ckpt_keep(args.usize_or("ckpt-keep", 2));
        if let Some(saved) =
            optimus::ckpt::SavedCheckpoint::load_latest(std::path::Path::new(dir))
        {
            // informational only — the trainer's preflight owns the
            // actual resume decision (it may fall back past a damaged
            // slot or reject a different model)
            println!(
                "newest committed checkpoint: step {} (saved under `{}`)",
                saved.step, saved.plan
            );
        }
    }
    let spec = b.build()?;
    let r = coordinator::train(&man, &spec)?;
    for (s, l) in &r.loss.points {
        if s % args.usize_or("log-every", 5) == 0 {
            println!("step {s:>5}  loss {l:.4}");
        }
    }
    println!(
        "done: {:.0} tok/s, optimizer state {}B/rank, final loss {:.4}",
        r.tokens_per_sec(),
        r.opt_state_bytes,
        r.loss.last().unwrap_or(f64::NAN)
    );
    println!(
        "precision: --dtype {} ({} B/elem wires); collectives moved \
         {:.2} MiB in / {:.2} MiB out",
        spec.plan.dtype,
        spec.plan.dtype.bytes(),
        r.comm_bytes_in as f64 / (1 << 20) as f64,
        r.comm_bytes_out as f64 / (1 << 20) as f64,
    );
    if spec.plan.topo.node_size > 1 {
        println!(
            "hierarchy: --node-size {} — {:.2} MiB intra-node (Xe-Link) / \
             {:.2} MiB inter-node (fabric)",
            spec.plan.topo.node_size,
            r.comm_intra_bytes as f64 / (1 << 20) as f64,
            r.comm_inter_bytes as f64 / (1 << 20) as f64,
        );
    }
    println!(
        "data: {} instances ({:.2} epochs) consumed; stall {:.4}s ({}), \
         prefetch hid {:.4}s",
        r.instances_consumed,
        r.epochs_consumed,
        r.breakdown.data_secs + r.breakdown.data_wait_secs,
        if spec.plan.prefetch { "queue wait" } else { "synchronous reads" },
        r.breakdown.data_prefetch_secs
    );
    if spec.plan.overlap {
        println!(
            "overlap: hid {:.3}s of comm behind compute ({:.0}% of step comm)",
            r.breakdown.overlap_secs,
            100.0 * r.breakdown.overlap_ratio()
        );
    }
    if spec.plan.ckpt.enabled() {
        println!(
            "checkpoints: {} committed ({:.2} MiB shard payload); snapshot stall \
             {:.4}s, hidden write {:.4}s",
            r.ckpt_commits,
            r.ckpt_bytes as f64 / (1 << 20) as f64,
            r.breakdown.snapshot_secs,
            r.breakdown.snapshot_write_secs
        );
    }
    Ok(())
}

/// `optimus ckpt inspect <dir>` — print a checkpoint directory's
/// manifests: per slot, the step, recorded plan, shard files with
/// checksum status, and commit validity.
fn do_ckpt(args: &Args) -> optimus::Result<()> {
    check(args, CKPT_FLAGS)?;
    match args.positional.get(1).map(String::as_str) {
        Some("inspect") => {
            let dir = args
                .positional
                .get(2)
                .ok_or_else(|| anyhow!("usage: optimus ckpt inspect <dir>"))?;
            print!("{}", optimus::ckpt::inspect(std::path::Path::new(dir))?);
            Ok(())
        }
        _ => Err(anyhow!("usage: optimus ckpt inspect <dir>")),
    }
}

fn do_eval(args: &Args) -> optimus::Result<()> {
    check(args, EVAL_FLAGS)?;
    let model = args.str_or("model", "mula-tiny");
    let man = Manifest::load(&optimus::artifacts_dir())?;
    let mm = man.config(&model)?;
    let engine = Engine::new_pool(2)?;
    let params = optimus::runtime::Tensor::f32(
        coordinator::init_global_params(mm, args.usize_or("seed", 0) as u64),
        vec![mm.param_count],
    );
    let scores = eval::run_suite(&engine, mm, &params, args.usize_or("cases", 16))?;
    for (t, s) in &scores {
        println!("{t:<14} {s:6.1}");
    }
    println!("{:<14} {:6.1}", "average", eval::average(&scores));
    Ok(())
}

/// `optimus serve` — expert-parallel inference from a training
/// checkpoint: load + reassemble the newest committed checkpoint, slice
/// it onto a dp×ep serving mesh, and replay a bounded seeded open-loop
/// workload through the continuous-batching scheduler and paged KV
/// cache. The exit code enforces the bounded-run contract — every
/// offered request completed and zero KV pages leaked — which is what
/// CI's serve-smoke job runs.
fn do_serve(args: &Args) -> optimus::Result<()> {
    check(args, SERVE_FLAGS)?;
    let model = args.str_or("model", "mula-tiny");
    let ckpt = args
        .get("ckpt-dir")
        .ok_or_else(|| anyhow!("serve needs --ckpt-dir DIR (a training run's checkpoint root)"))?;
    let man = Manifest::load(&optimus::artifacts_dir())?;
    let mut cfg = ServeConfig::new(&model, std::path::Path::new(ckpt));
    cfg.topo = Topology::grid(args.usize_or("dp", 1), args.usize_or("ep", 1), 1);
    cfg.mode =
        if args.bool_or("static", false) { BatchMode::Static } else { BatchMode::Continuous };
    cfg.kv_pages = args.usize_or("kv-pages", 16);
    cfg.kv_page_size = args.usize_or("kv-page-size", 8);
    cfg.engine_pool = args.usize_or("pool", 0);
    cfg.traffic = TrafficConfig {
        seed: args.usize_or("seed", 0) as u64,
        requests: args.usize_or("requests", 16),
        rate_rps: args.f64_or("rate", 0.0),
        prompt_len: (args.usize_or("prompt-min", 4), args.usize_or("prompt-max", 8)),
        gen_len: (args.usize_or("gen-min", 4), args.usize_or("gen-max", 12)),
        queue_depth: args.usize_or("queue-depth", 4),
    };
    let r = optimus::serve::serve(&man, &cfg)?;
    println!(
        "served {}/{} requests from the step-{} checkpoint on dp{}×ep{} ({})",
        r.completions.len(),
        r.submitted,
        r.resumed_step,
        cfg.topo.dp,
        cfg.topo.ep,
        match cfg.mode {
            BatchMode::Continuous => "continuous batching",
            BatchMode::Static => "static batching",
        },
    );
    println!(
        "ttft p50 {:.4}s p99 {:.4}s; per-token p50 {:.4}s p99 {:.4}s",
        r.ttft.p50(),
        r.ttft.p99(),
        r.per_token.p50(),
        r.per_token.p99(),
    );
    println!(
        "{} tokens in {} decode steps over {:.3}s — {:.0} tok/s",
        r.tokens_generated,
        r.decode_steps,
        r.wall_secs,
        r.tokens_per_sec(),
    );
    println!(
        "kv: peak {} of {} pages, {} leaked",
        r.kv_pages_peak, r.kv_pages_total, r.kv_pages_leaked
    );
    if let Some(path) = args.get("json") {
        let js = format!(
            "{{\n  \"completed\": {},\n  \"submitted\": {},\n  \"ttft_p50_secs\": {},\n  \
             \"ttft_p99_secs\": {},\n  \"per_token_p50_secs\": {},\n  \
             \"per_token_p99_secs\": {},\n  \"tokens_per_sec\": {},\n  \
             \"decode_steps\": {},\n  \"kv_pages_peak\": {},\n  \"kv_pages_leaked\": {}\n}}\n",
            r.completions.len(),
            r.submitted,
            r.ttft.p50(),
            r.ttft.p99(),
            r.per_token.p50(),
            r.per_token.p99(),
            r.tokens_per_sec(),
            r.decode_steps,
            r.kv_pages_peak,
            r.kv_pages_leaked,
        );
        std::fs::write(path, js).map_err(|e| anyhow!("cannot write --json `{path}`: {e}"))?;
    }
    if r.completions.len() != r.submitted {
        return Err(anyhow!(
            "incomplete serve run: {} of {} requests completed",
            r.completions.len(),
            r.submitted
        ));
    }
    if r.kv_pages_leaked != 0 {
        return Err(anyhow!(
            "kv page leak: {} page(s) still held after every lane drained",
            r.kv_pages_leaked
        ));
    }
    Ok(())
}

/// Sweep tooling: list every dp×ep×pp placement of a world size; with
/// `--model`, mark which placements the built artifacts can run — using
/// the same validation table `train` enforces, so the two never drift —
/// and report each runnable placement's per-step data consumption
/// (instances and tokens, from the same `batch_plan` the engines read
/// through). With `--data`, also the epochs a `--steps`-long run eats.
fn do_plans(args: &Args) -> optimus::Result<()> {
    check(args, PLANS_FLAGS)?;
    let world = args.usize_or("world", 8);
    let steps = args.usize_or("steps", 50);
    let dtype = Dtype::parse(&args.str_or("dtype", "f32"))?;
    // resident memory per rank, in bytes per model parameter:
    // params + grads at the dtype's width, plus AdamW moments (always
    // f32 pairs) and — under bf16 — the f32 master copy, spread over
    // the dp×ep shard group (the EPSO layout; SO replicates NE states)
    let opt_bytes_per_param: f64 = match dtype {
        Dtype::F32 => 8.0,
        Dtype::Bf16 => 12.0,
    };
    let man = args
        .get("model")
        .map(|_| Manifest::load(&optimus::artifacts_dir()))
        .transpose()?;
    let mm = match (&man, args.get("model")) {
        (Some(man), Some(model)) => Some(man.config(model)?),
        _ => None,
    };
    let ds = args
        .get("data")
        .map(|d| optimus::data::Dataset::open(std::path::Path::new(d)))
        .transpose()?;
    if let Some(ds) = &ds {
        println!(
            "dataset: {} instances of context {} ({} tokens)",
            ds.len(),
            ds.context,
            ds.len() * ds.context
        );
    }
    println!("dp×ep×pp placements of world={world}:");
    for t in ParallelismPlan::enumerate(world) {
        let plan = ParallelismPlan::new(t);
        let note = match mm {
            Some(mm) if plan.validate_model(mm).is_ok() => {
                let bp = plan.batch_plan(mm);
                let ips = bp.instances_per_step();
                let mut n = format!(
                    "  runnable: {ips} inst/step, {} tok/step, {:.2} B/param \
                     ({} params+grads, opt/{} ranks)",
                    ips * mm.hyper.seq,
                    (dtype.bytes() * 2) as f64
                        + opt_bytes_per_param / (t.dp * t.ep) as f64,
                    dtype.bytes() * 2,
                    t.dp * t.ep,
                );
                if let Some(ds) = &ds {
                    n.push_str(&format!(
                        ", {steps} steps = {:.2} epochs",
                        (steps * ips) as f64 / ds.len() as f64
                    ));
                }
                n
            }
            _ => String::new(),
        };
        println!("  dp={:<3} ep={:<3} pp={:<3}{note}", t.dp, t.ep, t.pp);
    }
    Ok(())
}

/// `optimus lint` — run the crate's invariant lint (see
/// `optimus::analysis`) and fail loudly on any violation. CI runs this
/// as a blocking job; `--root` points it at a different checkout.
/// `--json`/`--sarif` write machine-readable findings (SARIF feeds
/// GitHub code scanning) carrying exactly the human-format findings.
fn do_lint(args: &Args) -> optimus::Result<()> {
    use optimus::ft::checks;
    check(args, LINT_FLAGS)?;
    let root = args
        .get("root")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(optimus::analysis::default_root);
    let t0 = std::time::Instant::now();
    let violations = optimus::analysis::run(&root)?;
    let secs = t0.elapsed().as_secs_f64();
    if let Some(p) = args.get("json") {
        std::fs::write(p, optimus::analysis::to_json(&violations))?;
    }
    if let Some(p) = args.get("sarif") {
        std::fs::write(p, optimus::analysis::to_sarif(&violations, "rust/"))?;
    }
    if violations.is_empty() {
        println!(
            "lint clean: {} passes, {} registered checks, 0 violations ({secs:.2}s)",
            optimus::analysis::RULES.len(),
            checks::CHECKS.len()
        );
        return Ok(());
    }
    for v in &violations {
        eprintln!("{v}");
    }
    for rule in optimus::analysis::RULES {
        let n = violations.iter().filter(|v| v.rule == *rule).count();
        if n > 0 {
            eprintln!("{}", checks::msg(checks::LINT, *rule, format_args!("{n} finding(s)")));
        }
    }
    Err(anyhow!("lint failed with {} violation(s) in {secs:.2}s", violations.len()))
}

/// `optimus predict <bench.json>` — run the cluster analytic model
/// against a measured perf-gate bench file and report per-term
/// prediction error. Absolute step times on this in-process testbed say
/// nothing about Aurora wall clock, so the validated terms are the
/// dimensionless ratios both sides define: bf16/f32 collective bytes,
/// hierarchical/flat inter-node bytes, and the `--overlap` speedup.
/// Bench values that are absent or zero (e.g. the committed zeroed
/// `ci/bench_baseline.json`) report as record-only instead of failing,
/// so CI can smoke the loop before a measured bench lands.
fn do_predict(args: &Args) -> optimus::Result<()> {
    check(args, PREDICT_FLAGS)?;
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: optimus predict <bench.json> [--model M] [--fur]"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read bench file `{path}`: {e}"))?;
    let bench = optimus::util::json::Json::parse(&text)
        .map_err(|e| anyhow!("bench file `{path}`: {e}"))?;
    let model = args.str_or("model", "mula-220b-a10b");
    let spec = MulaSpec::by_name(&model)
        .ok_or_else(|| anyhow!("--model wants a paper config (Table 1), got `{model}`"))?;
    let hw = Aurora::default();
    let plan = ParallelPlan {
        dp: 32,
        ep: 12,
        pp: 8,
        micro_batches: 16,
        schedule: Schedule::OneFOneB,
        tokens_per_tile: 4096,
        fur: args.bool_or("fur", false),
        wire_bytes: 2.0,
        node_size: hw.tiles_per_node,
    };
    let s = cluster::step_time(spec, &hw, &plan, true);
    println!(
        "analytic step model for {} (dp{} ep{} pp{}, {} tiles/node):",
        spec.name, plan.dp, plan.ep, plan.pp, plan.node_size
    );
    for (term, secs) in [
        ("compute", s.compute),
        ("dp_comm", s.dp_comm),
        ("ep_comm", s.ep_comm),
        ("pp_bubble", s.pp_bubble),
        ("optimizer", s.optimizer),
    ] {
        println!("  {term:<10} {secs:>9.4}s  ({:>4.1}%)", 100.0 * secs / s.total());
    }
    println!("  {:<10} {:>9.4}s", "total", s.total());

    // the bench's own node size (the hier lane's --node-size) decides the
    // traffic-ratio prediction; older bench files without the key get the
    // machine default
    let node_size = bench
        .get("hier_node_size")
        .and_then(optimus::util::json::Json::as_usize)
        .unwrap_or(hw.tiles_per_node);
    let num = |k: &str| bench.get(k).and_then(optimus::util::json::Json::as_f64).filter(|v| *v > 0.0);
    let ratio = |a: &str, b: &str| Some(num(a)? / num(b)?);
    let terms: Vec<(String, f64, Option<f64>)> = vec![
        (
            "bf16/f32 collective bytes".to_string(),
            ParallelPlan::wire_bytes_for("bf16") / ParallelPlan::wire_bytes_for("f32"),
            ratio("dp_bf16_comm_bytes", "dp_f32_comm_bytes"),
        ),
        (
            format!("hier/flat inter-node bytes (node_size {node_size})"),
            hier_inter_traffic_ratio(node_size),
            ratio("dp_hier_inter_bytes", "dp_flat_inter_bytes"),
        ),
        (
            "overlap speedup (dp)".to_string(),
            s.overlap_speedup(),
            ratio("dp_overlap_steps_per_sec", "dp_serial_steps_per_sec"),
        ),
    ];
    println!("\nper-term model validation against `{path}`:");
    let mut worst: Option<f64> = None;
    for (name, pred, meas) in terms {
        match meas {
            Some(m) => {
                let err = (pred - m).abs() / m.abs().max(f64::MIN_POSITIVE);
                worst = Some(worst.unwrap_or(0.0).max(err));
                println!(
                    "  {name:<44} predicted {pred:>7.3}  measured {m:>7.3}  error {:>5.1}%",
                    err * 100.0
                );
            }
            None => println!(
                "  {name:<44} predicted {pred:>7.3}  measured —  (record-only: \
                 bench value absent or zero)"
            ),
        }
    }
    match worst {
        Some(w) => println!("worst per-term relative error: {:.1}%", w * 100.0),
        None => println!("no measured terms in `{path}` — model breakdown recorded above"),
    }
    Ok(())
}

fn do_scaling(args: &Args) -> optimus::Result<()> {
    check(args, SCALING_FLAGS)?;
    let hw = Aurora::default();
    let fur = args.bool_or("fur", false);
    let model = args.str_or("model", "mula-220b-a10b");
    let spec: &MulaSpec = MulaSpec::by_name(&model).unwrap_or(&MULA_220B);
    println!("tiles  nodes  efficiency (fur={fur})");
    for tiles in [384usize, 768, 1536, 3072, 6144, 12288] {
        println!(
            "{tiles:>6} {:>6} {:>8.3}",
            tiles / 12,
            scaling_efficiency(spec, &hw, 384, tiles, fur)
        );
    }
    Ok(())
}
