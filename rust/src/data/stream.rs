//! The shuffled **token stream** and the elastic-resume **token cursor**.
//!
//! A training run consumes one logical stream of instances: stream
//! position `p` maps through the epoch-aware [`ShuffledIndex`] to a raw
//! instance, and the whole stream is bounded by the run's validated
//! **budget** (`steps × instances_per_step`, counted from the resume
//! cursor). Every read path goes through here — a raw index escaping the
//! budget is a hard `data read past validated budget` error, never a
//! silent wrap (DESIGN.md §7).
//!
//! [`TokenCursor`] is the resume contract: `instances consumed so far`
//! is checkpointed as a `StatePart` scalar, and a resumed run — under
//! *any* topology — continues at exactly the next unseen stream
//! position. Deriving the position from `step × instances_per_step`
//! (the pre-cursor scheme) silently re-read or skipped data whenever the
//! resumed geometry changed the per-step instance count.

use super::dataset::Dataset;
use super::shuffle::ShuffledIndex;
use super::tokenizer::EOS;
use crate::Result;
use anyhow::anyhow;
use std::sync::Arc;

/// Global data position of a run: `base` instances were consumed before
/// `start_step` (0 on fresh runs, the checkpointed cursor on resume),
/// and every step consumes `per_step` more under the current
/// [`BatchPlan`](super::BatchPlan) geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenCursor {
    /// instances consumed before `start_step` (the checkpointed scalar)
    pub base: u64,
    /// first step this run executes (`saved step + 1` on resume)
    pub start_step: usize,
    /// instances per optimizer step under the *current* plan geometry
    pub per_step: u64,
}

impl TokenCursor {
    /// Fresh-run cursor: position 0, counting from step 0.
    pub fn fresh(per_step: u64) -> TokenCursor {
        TokenCursor { base: 0, start_step: 0, per_step }
    }

    /// Stream position where `step` begins. Saturates below `start_step`
    /// (a resumed run whose checkpoint already met the step budget).
    pub fn at_step(&self, step: usize) -> u64 {
        self.base + step.saturating_sub(self.start_step) as u64 * self.per_step
    }
}

/// The run's bounded, shuffled instance stream: dataset + shuffle index
/// + validated budget. Shared (`Arc`) by every rank and by the prefetch
/// producers.
pub struct TokenStream {
    ds: Arc<Dataset>,
    index: ShuffledIndex,
    /// valid stream positions are `[0, budget)`
    budget: u64,
    /// where the *logical* stream ends (`dataset × epoch budget`;
    /// `u64::MAX` when the epoch budget is unbounded). Target-token
    /// continuation EOS-pads only here — never at the run-dependent
    /// `budget` wall, so the tokens at a given position are identical
    /// whatever step count or resume point a run has.
    stream_end: u64,
}

impl TokenStream {
    /// Stream over `ds`, shuffled by `data_seed`, with `budget` total
    /// instance reads (the run's validated data budget). The logical
    /// stream end defaults to unbounded (epochs wrap forever); bound it
    /// with [`TokenStream::with_stream_end`].
    pub fn new(ds: Arc<Dataset>, data_seed: u64, budget: u64) -> TokenStream {
        let index = ShuffledIndex::new(ds.len(), data_seed);
        TokenStream { ds, index, budget, stream_end: u64::MAX }
    }

    /// Bound the logical stream at `end` positions (a `data_epochs`
    /// budget): continuation targets EOS-pad there, the true end of the
    /// data.
    pub fn with_stream_end(mut self, end: u64) -> TokenStream {
        self.stream_end = end;
        self
    }

    /// Instances per epoch (the dataset length).
    pub fn epoch_len(&self) -> u64 {
        self.index.epoch_len()
    }

    /// Total validated stream positions.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Map a stream position to `(epoch, instance id)`, enforcing the
    /// budget.
    pub fn map(&self, pos: u64) -> Result<(u64, usize)> {
        if pos >= self.budget {
            return Err(anyhow!(
                "data read past validated budget: stream position {pos} is outside the \
                 run's {} validated instance reads",
                self.budget
            ));
        }
        Ok(self.index.map(pos))
    }

    /// Batch of `rows` consecutive *stream* positions starting at `pos`,
    /// each extended to `seq+1` tokens. Token `seq` (the last target) is
    /// the first token of the **next stream slot** when the slot exists;
    /// EOS-padding happens only at the true stream end (`stream_end` —
    /// never at the run-dependent read budget, so batch contents are a
    /// pure function of position). Within a shuffle block the positions
    /// are consecutive raw instances, so the mmap reads stay contiguous.
    pub fn batch_i32(&self, pos: u64, rows: usize, seq: usize) -> Result<Vec<i32>> {
        let c = self.ds.context;
        let mut out = Vec::with_capacity(rows * (seq + 1));
        for r in 0..rows {
            let p = pos + r as u64;
            let mut ext = self.ds.instance(self.map(p)?.1)?;
            // continuation: tokens past the instance come from the
            // following stream slots (a read-only lookahead — it may
            // peek past the budget wall, never past the stream end)
            while ext.len() < seq + 1 {
                let next = p + (ext.len() / c) as u64;
                if next >= self.stream_end {
                    break;
                }
                let more = self.ds.instance(self.index.map(next).1)?;
                ext.extend(more);
            }
            for j in 0..=seq {
                out.push(*ext.get(j).unwrap_or(&EOS) as i32);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, preprocess};

    fn stream(tag: &str, budget: u64) -> (std::path::PathBuf, TokenStream) {
        let dir = std::env::temp_dir()
            .join(format!("optimus-stream-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = corpus::data_files(5, 4, 16);
        preprocess::preprocess(&files, 32, 11, &dir, 64).unwrap();
        let ds = Arc::new(Dataset::open(&dir).unwrap());
        let st = TokenStream::new(Arc::clone(&ds), 21, budget);
        (dir, st)
    }

    #[test]
    fn cursor_arithmetic_and_saturation() {
        let fresh = TokenCursor::fresh(8);
        assert_eq!(fresh.at_step(0), 0);
        assert_eq!(fresh.at_step(5), 40);
        // resumed under a different geometry: continues at base exactly
        let resumed = TokenCursor { base: 40, start_step: 5, per_step: 16 };
        assert_eq!(resumed.at_step(5), 40);
        assert_eq!(resumed.at_step(7), 72);
        // checkpoint at/past the step budget: no underflow, zero progress
        assert_eq!(resumed.at_step(3), 40);
    }

    #[test]
    fn budget_is_a_hard_wall() {
        let (dir, st) = stream("budget", 10);
        assert!(st.map(9).is_ok());
        let e = st.map(10).unwrap_err().to_string();
        assert!(e.contains("data read past validated budget"), "{e}");
        // a batch straddling the wall fails too
        let e = st.batch_i32(8, 4, 8).unwrap_err().to_string();
        assert!(e.contains("data read past validated budget"), "{e}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let (dir, a) = stream("det", 1000);
        let ds = Arc::new(Dataset::open(&dir).unwrap());
        let b = TokenStream::new(Arc::clone(&ds), 21, 1000);
        let c = TokenStream::new(ds, 22, 1000);
        let (x, y) = (a.batch_i32(7, 4, 31).unwrap(), b.batch_i32(7, 4, 31).unwrap());
        assert_eq!(x, y, "same data seed must give the same stream");
        let n = a.epoch_len();
        assert_ne!(
            (0..n).map(|p| a.map(p).unwrap().1).collect::<Vec<_>>(),
            (0..n).map(|p| c.map(p).unwrap().1).collect::<Vec<_>>(),
            "different data seeds must reorder"
        );
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn epochs_reshuffle_but_cover_everything() {
        let (dir, st) = stream("epochs", u64::MAX);
        let n = st.epoch_len();
        let e0: Vec<usize> = (0..n).map(|p| st.map(p).unwrap().1).collect();
        let e1: Vec<usize> = (0..n).map(|p| st.map(n + p).unwrap().1).collect();
        assert_ne!(e0, e1, "epoch 1 must be reshuffled");
        let mut s0 = e0.clone();
        let mut s1 = e1.clone();
        s0.sort_unstable();
        s1.sort_unstable();
        assert_eq!(s0, s1, "every epoch covers every instance exactly once");
        assert_eq!(s0, (0..n as usize).collect::<Vec<_>>());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn last_target_is_next_slots_first_token() {
        let (dir, st) = stream("target", 1000);
        let c = 32;
        // seq == context: token index c must be the next stream slot's
        // first token, not EOS
        let b = st.batch_i32(3, 2, c).unwrap();
        assert_eq!(b.len(), 2 * (c + 1));
        for r in 0..2u64 {
            let next_first = st.ds.instance(st.map(3 + r + 1).unwrap().1).unwrap()[0];
            assert_eq!(b[(r as usize) * (c + 1) + c], next_first as i32, "row {r}");
        }
        // at the true stream end (an epoch budget) there is no next
        // slot: EOS. The *read budget* is deliberately NOT a wall for
        // continuation — batch contents must not depend on a run's step
        // count or resume point.
        let (dir2, tiny) = stream("target-end", 4);
        let tiny = tiny.with_stream_end(4);
        let e = tiny.batch_i32(3, 1, c).unwrap();
        assert_eq!(e[c], EOS as i32);
        // same position, same seed, bigger budget but same stream end:
        // identical row
        let ds2 = Arc::new(Dataset::open(&dir2).unwrap());
        let wider = TokenStream::new(ds2, 21, 1000).with_stream_end(4);
        assert_eq!(wider.batch_i32(3, 1, c).unwrap(), e);
        std::fs::remove_dir_all(dir).unwrap();
        std::fs::remove_dir_all(dir2).unwrap();
    }
}
