//! Integration: the four runnable engines (DP-fused, EP, PP, hybrid
//! PP×EP) implement the *same* training semantics — first-step losses
//! agree across decompositions on identical data, every mode learns, and
//! the hybrid's parameter trajectory matches DP's.

use optimus::comm::Topology;
use optimus::coordinator::{self, ep::EpComm, pipeline::Schedule, JobSpec, JobSpecBuilder};
use optimus::data::{corpus, preprocess};
use optimus::optim::ShardingMode;
use optimus::runtime::Dtype;
use std::path::PathBuf;
use std::sync::OnceLock;

fn data_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("optimus-it-data-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = corpus::data_files(42, 4, 24);
        preprocess::preprocess(&files, 64, 7, &dir, 256).unwrap();
        dir
    })
    .clone()
}

fn base(topo: Topology, steps: usize) -> JobSpecBuilder {
    JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topo(topo)
        .steps(steps)
        .warmup_steps(4)
        .peak_lr(2e-3)
        .min_lr(2e-4)
        .engine_pool(2)
}

#[test]
fn dp_ep_pp_first_step_losses_agree() {
    let Some(m) = optimus::manifest_or_skip("train_modes::dp_ep_pp_first_step_losses_agree") else {
        return;
    };

    let dp = coordinator::train(&m, &base(Topology::dp_only(2), 2).build().unwrap()).unwrap();
    let ep_spec = base(Topology::grid(1, 2, 1), 2)
        .sharding(ShardingMode::Epso)
        .build()
        .unwrap();
    let ep = coordinator::train(&m, &ep_spec).unwrap();
    let pp_spec = base(Topology::grid(1, 1, 2), 2)
        .micro_batches(2)
        .schedule(Schedule::OneFOneB)
        .build()
        .unwrap();
    let pp = coordinator::train(&m, &pp_spec).unwrap();

    let l_dp = dp.loss.points[0].1;
    let l_ep = ep.loss.points[0].1;
    let l_pp = pp.loss.points[0].1;
    // identical params + identical data: decompositions must agree
    assert!((l_dp - l_ep).abs() < 5e-4, "DP {l_dp} vs EP {l_ep}");
    assert!((l_dp - l_pp).abs() < 5e-4, "DP {l_dp} vs PP {l_pp}");
    // random init on vocab 256 -> ~ln(256)
    assert!((l_dp - 256f64.ln()).abs() < 0.5, "{l_dp}");
}

#[test]
fn every_mode_learns() {
    let Some(m) = optimus::manifest_or_skip("train_modes::every_mode_learns") else {
        return;
    };
    let steps = 25;

    let dp = coordinator::train(&m, &base(Topology::dp_only(2), steps).build().unwrap()).unwrap();
    assert!(
        dp.loss.tail_mean(3) < dp.loss.points[0].1 - 0.5,
        "DP no learning: {:?}",
        dp.loss.points
    );

    let ep_spec = base(Topology::grid(1, 2, 1), steps)
        .sharding(ShardingMode::Epso)
        .build()
        .unwrap();
    let ep = coordinator::train(&m, &ep_spec).unwrap();
    assert!(
        ep.loss.tail_mean(3) < ep.loss.points[0].1 - 0.5,
        "EP no learning: {:?}",
        ep.loss.points
    );

    let pp_spec = base(Topology::grid(1, 1, 2), steps)
        .micro_batches(2)
        .build()
        .unwrap();
    let pp = coordinator::train(&m, &pp_spec).unwrap();
    assert!(
        pp.loss.tail_mean(3) < pp.loss.points[0].1 - 0.5,
        "PP no learning: {:?}",
        pp.loss.points
    );
}

#[test]
fn pp_ep_hybrid_matches_dp_and_learns() {
    // The PP×EP acceptance gate: a (dp=1, ep=2, pp=2) JobSpec trains ≥10
    // steps through harness::run; the loss curve is finite and
    // decreasing; and — because all engines share the
    // mean-over-global-batch gradient convention and the world-group
    // clip domain — its final parameters match a DP-only run of the same
    // seed/steps within fp32 reduction tolerance.
    let Some(m) = optimus::manifest_or_skip("train_modes::pp_ep_hybrid_matches_dp_and_learns")
    else {
        return;
    };
    let steps = 12;
    let dp_spec = base(Topology::dp_only(2), steps)
        .bf16_grad_reduce(false)
        .build()
        .unwrap();
    let dp = coordinator::train(&m, &dp_spec).unwrap();

    let hy_spec = base(Topology::grid(1, 2, 2), steps)
        .sharding(ShardingMode::Epso)
        .schedule(Schedule::OneFOneB)
        .micro_batches(1) // one microbatch per data rank = DP's global batch
        .bf16_grad_reduce(false)
        .build()
        .unwrap();
    let hy = coordinator::train(&m, &hy_spec).unwrap();

    assert!(hy.loss.points.len() >= 10, "only {} steps", hy.loss.points.len());
    for (_, l) in &hy.loss.points {
        assert!(l.is_finite(), "{:?}", hy.loss.points);
    }
    assert!(
        hy.loss.tail_mean(3) < hy.loss.points[0].1 - 0.3,
        "hybrid no learning: {:?}",
        hy.loss.points
    );
    // same decomposition: step-0 losses identical, trajectories match
    let (l_dp, l_hy) = (dp.loss.points[0].1, hy.loss.points[0].1);
    assert!((l_dp - l_hy).abs() < 5e-4, "DP {l_dp} vs PP×EP {l_hy}");
    let a = dp.final_params.as_f32().unwrap();
    let b = hy.final_params.as_f32().unwrap();
    assert_eq!(a.len(), b.len());
    let mut max_diff = 0.0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        max_diff = max_diff.max((x - y).abs());
    }
    assert!(
        max_diff < 1e-2,
        "hybrid diverged from DP: max |Δparam| = {max_diff}"
    );
}

#[test]
fn pp_ep_hybrid_microbatched_gpipe_stays_finite() {
    // schedule × microbatch coverage for the hybrid: GPipe with 2
    // microbatches per (dp, ep) data rank
    let Some(m) =
        optimus::manifest_or_skip("train_modes::pp_ep_hybrid_microbatched_gpipe_stays_finite")
    else {
        return;
    };
    let spec = base(Topology::grid(1, 2, 2), 4)
        .schedule(Schedule::GPipe)
        .micro_batches(2)
        .build()
        .unwrap();
    let r = coordinator::train(&m, &spec).unwrap();
    assert_eq!(r.loss.points.len(), 4);
    for (_, l) in &r.loss.points {
        assert!(l.is_finite());
    }
}

#[test]
fn overlap_matches_serial_bitwise() {
    // the PR-3 acceptance gate: `--overlap` (pipelined sharded optimizer
    // over the async comm runtime) must be a pure scheduling change —
    // final parameters bit-identical to the serial optimizer, on both the
    // DP engine and the pipelined-EPSO dp×ep topology. A small chunk
    // forces several pipeline chunks per segment on mula-tiny.
    let Some(m) = optimus::manifest_or_skip("train_modes::overlap_matches_serial_bitwise")
    else {
        return;
    };
    for topo in [Topology::dp_only(2), Topology::grid(2, 2, 1)] {
        let run = |overlap: bool| {
            let mut b = base(topo, 6).overlap(overlap).overlap_chunk(4096);
            if topo.ep > 1 {
                b = b.sharding(ShardingMode::Epso);
            }
            coordinator::train(&m, &b.build().unwrap()).unwrap()
        };
        let serial = run(false);
        let piped = run(true);
        let a = serial.final_params.as_f32().unwrap();
        let b = piped.final_params.as_f32().unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "dp{} ep{}: param {i} diverged under --overlap: {x} vs {y}",
                topo.dp,
                topo.ep
            );
        }
        // falsifiable liveness: the overlapped run must actually have
        // gone through the comm lane (bit-identity alone would pass
        // vacuously if --overlap silently fell back to the serial step)
        assert!(
            piped.optimizer_lane_ops > 0,
            "dp{} ep{}: --overlap ran 0 lane collectives (serial fallback?)",
            topo.dp,
            topo.ep
        );
        assert_eq!(
            serial.optimizer_lane_ops, 0,
            "serial run unexpectedly used a comm lane"
        );
    }
}

#[test]
fn ep_so_and_epso_trajectories_match() {
    // EPSO is a resharding, not a different optimizer: loss curves must
    // coincide while EPSO holds strictly less optimizer state.
    let Some(m) = optimus::manifest_or_skip("train_modes::ep_so_and_epso_trajectories_match") else {
        return;
    };
    let mk = |mode| {
        let spec = base(Topology::grid(2, 2, 1), 6)
            .sharding(mode)
            .bf16_grad_reduce(false) // keep reductions exactly associative-ish
            .build()
            .unwrap();
        coordinator::train(&m, &spec).unwrap()
    };
    let so = mk(ShardingMode::So);
    let epso = mk(ShardingMode::Epso);
    for ((s1, a), (s2, b)) in so.loss.points.iter().zip(epso.loss.points.iter()) {
        assert_eq!(s1, s2);
        assert!((a - b).abs() < 2e-3, "step {s1}: SO {a} vs EPSO {b}");
    }
    assert!(
        epso.opt_state_bytes < so.opt_state_bytes,
        "EPSO must hold less state: {} vs {}",
        epso.opt_state_bytes,
        so.opt_state_bytes
    );
}

#[test]
fn ep_allgather_and_all2all_agree() {
    // paper §3.1 Stage 1: the two exchange policies are numerically
    // identical (they differ in communication volume only).
    let Some(m) = optimus::manifest_or_skip("train_modes::ep_allgather_and_all2all_agree") else {
        return;
    };
    let mk = |policy| {
        let spec = base(Topology::grid(1, 2, 1), 3)
            .ep_comm(policy)
            .bf16_grad_reduce(false)
            .build()
            .unwrap();
        coordinator::train(&m, &spec).unwrap()
    };
    let ag = mk(EpComm::Allgather);
    let aa = mk(EpComm::All2All);
    for ((_, a), (_, b)) in ag.loss.points.iter().zip(aa.loss.points.iter()) {
        assert!((a - b).abs() < 1e-4, "allgather {a} vs all2all {b}");
    }
}

#[test]
fn gpipe_and_1f1b_agree() {
    let Some(m) = optimus::manifest_or_skip("train_modes::gpipe_and_1f1b_agree") else {
        return;
    };
    let mk = |sched| {
        let spec = base(Topology::grid(1, 1, 2), 3)
            .schedule(sched)
            .micro_batches(4)
            .bf16_grad_reduce(false)
            .build()
            .unwrap();
        coordinator::train(&m, &spec).unwrap()
    };
    let g = mk(Schedule::GPipe);
    let f = mk(Schedule::OneFOneB);
    for ((_, a), (_, b)) in g.loss.points.iter().zip(f.loss.points.iter()) {
        assert!((a - b).abs() < 1e-4, "gpipe {a} vs 1f1b {b}");
    }
}

#[test]
fn bf16_dp_tracks_f32_trajectory_at_half_wire_width() {
    // the mixed-precision acceptance gate: `--dtype bf16` (bf16 resident
    // params + activation/gradient wires, f32 master weights) tracks the
    // f32 loss trajectory within rounding tolerance, still learns, and
    // moves roughly half the collective bytes
    let Some(m) =
        optimus::manifest_or_skip("train_modes::bf16_dp_tracks_f32_trajectory")
    else {
        return;
    };
    let steps = 12;
    let f32_run = coordinator::train(
        &m,
        &base(Topology::dp_only(2), steps)
            .bf16_grad_reduce(false)
            .build()
            .unwrap(),
    )
    .unwrap();
    let bf16_run = coordinator::train(
        &m,
        &base(Topology::dp_only(2), steps)
            .dtype(Dtype::Bf16)
            .build()
            .unwrap(),
    )
    .unwrap();
    // trajectories coincide up to bf16 rounding, not bit-identity: the
    // same data and init, but every wire and resident param is rounded
    for ((s1, a), (s2, b)) in f32_run.loss.points.iter().zip(bf16_run.loss.points.iter()) {
        assert_eq!(s1, s2);
        assert!(b.is_finite(), "step {s1}: bf16 loss not finite");
        assert!((a - b).abs() < 0.25, "step {s1}: f32 {a} vs bf16 {b}");
    }
    assert!(
        bf16_run.loss.tail_mean(3) < bf16_run.loss.points[0].1 - 0.3,
        "bf16 no learning: {:?}",
        bf16_run.loss.points
    );
    // report contract: final params are always f32, whatever the run dtype
    assert_eq!(bf16_run.final_params.dtype(), Dtype::F32);
    // half-width wires: every param-sized collective (gradient reduction
    // AND the optimizer's param allgather) rides 2-byte frames, so total
    // traffic lands at ~50% of the all-f32 run (scalar collectives keep
    // the ratio from being exactly half)
    let f32_bytes = f32_run.comm_bytes_in + f32_run.comm_bytes_out;
    let bf16_bytes = bf16_run.comm_bytes_in + bf16_run.comm_bytes_out;
    assert!(f32_bytes > 0 && bf16_bytes > 0, "traffic counters wired");
    let ratio = bf16_bytes as f64 / f32_bytes as f64;
    assert!(
        ratio <= 0.55,
        "bf16 moved {bf16_bytes} bytes vs f32 {f32_bytes} (ratio {ratio:.3} > 0.55)"
    );
}

#[test]
fn fur_runs_and_stays_finite() {
    let Some(m) = optimus::manifest_or_skip("train_modes::fur_runs_and_stays_finite") else {
        return;
    };
    let spec = base(Topology::grid(1, 2, 1), 4)
        .fur(true)
        .build()
        .unwrap();
    let r = coordinator::train(&m, &spec).unwrap();
    for (_, l) in &r.loss.points {
        assert!(l.is_finite());
    }
}
