//! Byte-level tokenizer with reserved specials.
//!
//! The paper tokenizes with the OLMoE tokenizer; our substitution keeps the
//! same *pipeline contract* (documents → token ids → EOS-joined arrays)
//! with a byte vocabulary. Ids: 0 = PAD, 1 = EOS, 2 = BOS, bytes map to
//! 3..259. All model vocab sizes (>=256) cover this range.

pub const PAD: u32 = 0;
pub const EOS: u32 = 1;
pub const BOS: u32 = 2;
pub const BYTE_OFFSET: u32 = 3;

#[derive(Clone, Copy, Debug, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Tokenizer {
        Tokenizer
    }

    pub fn vocab_size(&self) -> usize {
        256 + BYTE_OFFSET as usize
    }

    /// Encode one document (no EOS; the pipeline appends it when packing).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32 + BYTE_OFFSET).collect()
    }

    /// Decode ids back to text (specials are dropped).
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&id| id >= BYTE_OFFSET && id < BYTE_OFFSET + 256)
            .map(|&id| (id - BYTE_OFFSET) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Tokenize a data file (list of documents) into one token array,
    /// documents joined with EOS — paper §4: "generate a token array Ti
    /// corresponding to the data file Di by tokenizing individual
    /// documents in Di and concatenating them with EOS token".
    pub fn tokenize_file(&self, docs: &[String]) -> Vec<u32> {
        let mut out = Vec::new();
        for d in docs {
            out.extend(self.encode(d));
            out.push(EOS);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let s = "hello, Aurora! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokenize_file_joins_with_eos() {
        let t = Tokenizer::new();
        let docs = vec!["ab".to_string(), "c".to_string()];
        let ids = t.tokenize_file(&docs);
        assert_eq!(ids.len(), 5);
        assert_eq!(ids[2], EOS);
        assert_eq!(ids[4], EOS);
    }

    #[test]
    fn ids_stay_in_vocab() {
        let t = Tokenizer::new();
        for id in t.encode("\u{00ff}\u{0000}xyz") {
            assert!((id as usize) < t.vocab_size());
        }
    }
}
