"""FastSparseMoE — Pallas kernels for Algorithm 1 (paper §3.1), stages 2-5.

The paper's five-stage SYCL data plane, re-thought for a TPU-style machine
(see DESIGN.md §7 Hardware-Adaptation):

  Stage 1 (token communication)  lives in Rust (allgather / reduce-scatter
           over the EP process group) — this module computes the *local*
           partial output of one EP rank, i.e. everything between the
           allgather and the reduce-scatter.
  Stage 2 (token counting)       `token_counts` Pallas kernel: the paper's
           thread↦row-block mapping becomes a grid over row-blocks with
           per-program partial-count rows; prefix sums as a jnp epilogue.
  Stage 3 (index generation)     `index_gen` Pallas kernel: base+offset
           layout identical to the paper (Figure 5), trash-slot stores give
           static shapes.
  Stage 4 (expert computation)   tile-aligned grouped GEMM ("merged expert
           weights", megablocks-style): routed tokens are laid out
           expert-sorted with each expert's segment padded to a tile
           multiple, so every tile multiplies against exactly one expert's
           weights and compute scales with *routed* tokens (T*K + NR*TILE),
           not with NR*T like the naive baseline.
  Stage 5 (output reduction)     forward and backward Pallas kernels,
           transcribing Algorithm 1 lines 82-113.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); they lower to plain HLO inside the same module as the L2
model, which is what the Rust runtime loads.

Static-shape capacities (XLA requirement):
  RTCAP  = T*K       upper bound on routed entries for this rank
  RTPAD  = T*K + NR*TILE   padded (tile-aligned) stage-4 row count
  trash slot         index RTCAP used as the target of masked-out stores
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_TBS = 8     # token block size (paper line 16)
DEFAULT_TILE = 8    # stage-4 row-tile (MXU-shaped on real hw; small for tests)

_INTERPRET = True   # CPU PJRT cannot run Mosaic custom-calls; see DESIGN.md


def _round_up(x, m):
    return (x + m - 1) // m * m


# ===========================================================================
# Stage 2 — token counting
# ===========================================================================

def _token_counts_kernel(indices_ref, partial_ref, expert_counts_ref, *,
                         n_start, nr):
    """One program per row-block: partial counts for the NR local experts.

    The paper's per-SYCL-thread counters (lines 25-37) become one VMEM row
    of the [TH, NR] partial-count matrix per grid program.
    """
    idx = indices_ref[...]                       # [TBS, K]
    local = (idx >= n_start) & (idx <= n_start + nr - 1)
    ln = jnp.clip(idx - n_start, 0, nr - 1)
    onehot = jax.nn.one_hot(ln, nr, dtype=jnp.int32) * local[..., None].astype(jnp.int32)
    partial_ref[...] = jnp.sum(onehot, axis=(0, 1))[None, :]       # [1, NR]
    expert_counts_ref[...] = jnp.sum(local.astype(jnp.int32), axis=1)  # [TBS]


def token_counts(indices, n_start, nr, tbs=DEFAULT_TBS):
    """Stage 2. indices [T,K] int32 -> routing count metadata.

    Returns (partial_token_counts [NR*TH], partial_cum [NR*TH+1],
    cum_token_counts [NR+1], expert_counts [T], cum_expert_counts [T+1]),
    in the paper's expert-major ``ln*TH + tid`` layout.
    """
    t_tot, k = indices.shape
    assert t_tot % tbs == 0, (t_tot, tbs)
    th = t_tot // tbs
    partial_2d, expert_counts = pl.pallas_call(
        functools.partial(_token_counts_kernel, n_start=n_start, nr=nr),
        grid=(th,),
        in_specs=[pl.BlockSpec((tbs, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, nr), lambda i: (i, 0)),
            pl.BlockSpec((tbs,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((th, nr), jnp.int32),
            jax.ShapeDtypeStruct((t_tot,), jnp.int32),
        ],
        interpret=_INTERPRET,
    )(indices)
    # epilogue (paper lines 39-43): expert-major flatten + prefix sums
    partial = jnp.transpose(partial_2d).reshape(nr * th)          # ln*TH+tid
    pcum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(partial)])
    cum_token = pcum[jnp.arange(nr + 1) * th]
    cum_expert = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(expert_counts)])
    return partial, pcum, cum_token, expert_counts, cum_expert


# ===========================================================================
# Stage 3 — index generation
# ===========================================================================

def _index_gen_kernel(indices_ref, pcum_ref, cum_expert_ref,
                      input_idx_ref, output_idx_ref, sel_k_ref, *,
                      n_start, nr, tbs, k, th, rtcap):
    """One program per row-block; scattered stores into the full arrays.

    Positions are global (base from the stage-2 prefix sums + per-program
    running offset), so the output refs are whole-array blocks; masked-out
    (non-local) entries are redirected to the trash slot RTCAP. Grid
    programs write disjoint positions — the revisiting semantics of a
    whole-array output block keep earlier programs' writes.
    """
    tid = pl.program_id(0)

    @pl.when(tid == 0)
    def _init():
        input_idx_ref[...] = jnp.full((rtcap + 1,), -1, jnp.int32)
        output_idx_ref[...] = jnp.full((rtcap + 1,), -1, jnp.int32)
        sel_k_ref[...] = jnp.full((rtcap + 1,), -1, jnp.int32)

    pcum = pcum_ref[...]
    cum_expert = cum_expert_ref[...]

    def body(i, counter):
        t = tid * tbs + i
        idx = indices_ref[i, :]                                   # [K]
        local = (idx >= n_start) & (idx <= n_start + nr - 1)
        ln = jnp.clip(idx - n_start, 0, nr - 1)
        base = pcum[ln * th + tid]                                # [K]
        offset = counter[ln]                                      # [K]
        pos = jnp.where(local, base + offset, rtcap)              # [K]
        o_base = cum_expert[t]
        o_off = jnp.cumsum(local.astype(jnp.int32)) - local.astype(jnp.int32)
        o_pos = jnp.where(local, o_base + o_off, rtcap)           # [K]
        for kk in range(k):  # K is small & static: unrolled
            input_idx_ref[pos[kk]] = t
            output_idx_ref[o_pos[kk]] = pos[kk]
            sel_k_ref[o_pos[kk]] = kk
        return counter.at[ln].add(local.astype(jnp.int32))

    jax.lax.fori_loop(0, tbs, body, jnp.zeros((nr,), jnp.int32))


def index_generation(indices, pcum, cum_expert, n_start, nr, tbs=DEFAULT_TBS):
    """Stage 3. Returns (input_indices, output_indices, selected_expert_k),
    each of length RTCAP+1 (= T*K + trash slot), -1 in unused slots."""
    t_tot, k = indices.shape
    th = t_tot // tbs
    rtcap = t_tot * k
    full1 = lambda n: pl.BlockSpec((n,), lambda i: (0,))
    outs = pl.pallas_call(
        functools.partial(_index_gen_kernel, n_start=n_start, nr=nr,
                          tbs=tbs, k=k, th=th, rtcap=rtcap),
        grid=(th,),
        in_specs=[
            pl.BlockSpec((tbs, k), lambda i: (i, 0)),
            full1(nr * th + 1),
            full1(t_tot + 1),
        ],
        out_specs=[full1(rtcap + 1)] * 3,
        out_shape=[jax.ShapeDtypeStruct((rtcap + 1,), jnp.int32)] * 3,
        interpret=_INTERPRET,
    )(indices, pcum, cum_expert)
    return outs


def routing_metadata(indices, n_start, nr, tbs=DEFAULT_TBS):
    """Stages 2+3 packaged: all integer routing metadata for this EP rank.

    Everything here is non-differentiable plumbing; callers treat the
    returned dict as constants (ints carry no tangents in JAX).
    """
    partial, pcum, cum_token, expert_counts, cum_expert = token_counts(
        indices, n_start, nr, tbs)
    input_idx, output_idx, sel_k = index_generation(
        indices, pcum, cum_expert, n_start, nr, tbs)
    return dict(
        partial_token_counts=partial,
        partial_cum_token_counts=pcum,
        cum_token_counts=cum_token,
        expert_counts=expert_counts,
        cum_expert_counts=cum_expert,
        input_indices=input_idx,
        output_indices=output_idx,
        selected_expert_indices=sel_k,
    )


# ===========================================================================
# Stage 4 — expert computation (tile-aligned grouped GEMM)
# ===========================================================================

def _grouped_mlp_fwd_kernel(x_ref, gate_ref, up_ref, down_ref, y_ref):
    """One program per row-tile; the tile's expert weights are selected by
    the BlockSpec index_map (every row in a tile belongs to one expert,
    guaranteed by the tile-aligned padding)."""
    x = x_ref[...]                                    # [TILE, H]
    g = jnp.dot(x, gate_ref[0], preferred_element_type=jnp.float32)
    u = jnp.dot(x, up_ref[0], preferred_element_type=jnp.float32)
    act = (g * jax.nn.sigmoid(g)) * u                 # SwiGLU
    y_ref[...] = jnp.dot(act, down_ref[0],
                         preferred_element_type=jnp.float32).astype(x.dtype)


def _grouped_mlp_bwd_kernel(x_ref, gate_ref, up_ref, down_ref, dy_ref,
                            first_ref, dx_ref, dgate_ref, dup_ref, ddown_ref):
    """Backward per row-tile, recomputing the forward activations from the
    stashed tile input (SAC-style, mirrors the paper's recompute policy).
    dW blocks are revisited by consecutive tiles of the same expert and
    accumulated; `first_ref` flags the first tile of each expert."""
    x = x_ref[...]
    gw, uw, dw = gate_ref[0], up_ref[0], down_ref[0]
    dy = dy_ref[...]
    g = jnp.dot(x, gw, preferred_element_type=jnp.float32)
    u = jnp.dot(x, uw, preferred_element_type=jnp.float32)
    sig = jax.nn.sigmoid(g)
    silu_g = g * sig
    act = silu_g * u
    dact = jnp.dot(dy, dw.T, preferred_element_type=jnp.float32)
    ddown_t = jnp.dot(act.T, dy, preferred_element_type=jnp.float32)
    du_pre = dact * silu_g                            # d(up_out)
    dsilu = dact * u * (sig + g * sig * (1 - sig))    # d(gate_out)
    dgate_t = jnp.dot(x.T, dsilu, preferred_element_type=jnp.float32)
    dup_t = jnp.dot(x.T, du_pre, preferred_element_type=jnp.float32)
    dx_ref[...] = (jnp.dot(dsilu, gw.T, preferred_element_type=jnp.float32)
                   + jnp.dot(du_pre, uw.T,
                             preferred_element_type=jnp.float32)).astype(x.dtype)
    first = first_ref[0] == 1

    @pl.when(first)
    def _():
        dgate_ref[0] = dgate_t
        dup_ref[0] = dup_t
        ddown_ref[0] = ddown_t

    @pl.when(jnp.logical_not(first))
    def _():
        dgate_ref[0] += dgate_t
        dup_ref[0] += dup_t
        ddown_ref[0] += ddown_t


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def grouped_mlp(xpad, gate_t, up_t, down_t, tile):
    """Tile-aligned grouped expert MLP.

    xpad   [RTPAD, H]  expert-sorted, tile-padded routed tokens
    gate_t/up_t [n_tiles, H, I], down_t [n_tiles, I, H]
        per-tile expert weights (jnp gather of the merged weight by
        tile_expert — the VMEM-resident weight block of DESIGN.md §7)
    Returns ypad [RTPAD, H].
    """
    return _grouped_mlp_fwd(xpad, gate_t, up_t, down_t, tile)[0]


def _grouped_mlp_fwd(xpad, gate_t, up_t, down_t, tile):
    rtpad, h = xpad.shape
    n_tiles = rtpad // tile
    i_dim = gate_t.shape[2]
    y = pl.pallas_call(
        _grouped_mlp_fwd_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h, i_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, i_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, i_dim, h), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rtpad, h), xpad.dtype),
        interpret=_INTERPRET,
    )(xpad, gate_t, up_t, down_t)
    return y, (xpad, gate_t, up_t, down_t)


def _grouped_mlp_bwd(tile, res, dy):
    xpad, gate_t, up_t, down_t = res
    rtpad, h = xpad.shape
    n_tiles = rtpad // tile
    i_dim = gate_t.shape[2]
    # every tile owns its own dW block here (weights were gathered
    # per-tile); the caller segment-sums dW back onto experts.
    first = jnp.ones((n_tiles,), jnp.int32)
    dx, dgate_t, dup_t, ddown_t = pl.pallas_call(
        _grouped_mlp_bwd_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h, i_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, i_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, i_dim, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, h), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h, i_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, i_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, i_dim, h), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rtpad, h), xpad.dtype),
            jax.ShapeDtypeStruct((n_tiles, h, i_dim), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, h, i_dim), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, i_dim, h), jnp.float32),
        ],
        interpret=_INTERPRET,
    )(xpad, gate_t, up_t, down_t, dy, first)
    return dx, dgate_t, dup_t, ddown_t


grouped_mlp.defvjp(_grouped_mlp_fwd, _grouped_mlp_bwd)


# ===========================================================================
# Stage 5 — output reduction (paper lines 82-113, fwd + bwd kernels)
# ===========================================================================

def _output_reduction_fwd_kernel(yflat_ref, weights_ref, sel_k_ref,
                                 out_idx_ref, cum_expert_ref, out_ref, *,
                                 k, tt, rtcap):
    """One program per token-tile; K-slot weighted accumulate (vectorized
    over the hidden dim — the natural VPU layout, DESIGN.md §7)."""
    tile = pl.program_id(0)
    yflat = yflat_ref[...]                         # [RTCAP+1, H] (trash row 0s)
    w = weights_ref[...]                           # [TT, K]
    sel_k = sel_k_ref[...]
    out_idx = out_idx_ref[...]
    cum_expert = cum_expert_ref[...]
    t0 = tile * tt
    toks = t0 + jnp.arange(tt)
    base = cum_expert[toks]                        # [TT]
    size = cum_expert[toks + 1] - base
    acc = jnp.zeros((tt, yflat.shape[1]), jnp.float32)
    for i in range(k):                             # K static, unrolled
        valid = i < size                           # [TT]
        j = jnp.where(valid, base + i, rtcap)      # [TT] entry ids
        kk = jnp.clip(sel_k[j], 0, k - 1)          # [TT]
        idx = jnp.where(valid, out_idx[j], rtcap)
        wv = jnp.where(valid, jnp.take_along_axis(w, kk[:, None], 1)[:, 0], 0.0)
        acc = acc + wv[:, None].astype(jnp.float32) * yflat[idx].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def _output_reduction_bwd_kernel(dout_ref, yflat_ref, weights_ref, sel_k_ref,
                                 out_idx_ref, cum_expert_ref,
                                 dy_ref, dw_ref, *, k, tt, rtcap):
    """Backward per token-tile (paper lines 98-113): scatter d(mlp_out) and
    d(weights). Entries are unique per (token, slot) so stores never race;
    trash-slot redirection keeps masked lanes harmless."""
    tile = pl.program_id(0)

    @pl.when(tile == 0)
    def _init():
        dy_ref[...] = jnp.zeros_like(dy_ref)

    dout = dout_ref[...]                           # [TT, H]
    w = weights_ref[...]                           # [TT, K]
    sel_k = sel_k_ref[...]
    out_idx = out_idx_ref[...]
    cum_expert = cum_expert_ref[...]
    dw_acc = jnp.zeros((tt, k), jnp.float32)
    t0 = tile * tt
    toks = t0 + jnp.arange(tt)
    base = cum_expert[toks]
    size = cum_expert[toks + 1] - base
    yflat = yflat_ref[...]
    for i in range(k):
        valid = i < size
        j = jnp.where(valid, base + i, rtcap)
        kk = jnp.clip(sel_k[j], 0, k - 1)
        idx = jnp.where(valid, out_idx[j], rtcap)
        wv = jnp.where(valid, jnp.take_along_axis(w, kk[:, None], 1)[:, 0], 0.0)
        contrib = wv[:, None].astype(jnp.float32) * dout.astype(jnp.float32)
        # scatter rows: each (token,slot) entry owns a distinct y row
        for r in range(tt):  # TT small & static
            dy_ref[idx[r]] = contrib[r].astype(dy_ref.dtype)
        wgrad = jnp.sum(yflat[idx].astype(jnp.float32)
                        * dout.astype(jnp.float32), axis=1)       # [TT]
        wgrad = jnp.where(valid, wgrad, 0.0)
        dw_acc = dw_acc + wgrad[:, None] * jax.nn.one_hot(kk, k, dtype=jnp.float32)
    dw_ref[...] = dw_acc.astype(dw_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def output_reduction(yflat, weights, sel_k, out_idx, cum_expert, tt):
    """Stage 5: weighted average of local expert outputs per token.

    yflat [RTCAP+1, H] (trash row at RTCAP), weights [T, K]
    -> partial output [T, H] (to be reduce-scattered across EP by Rust).
    """
    return _output_reduction_fwd(yflat, weights, sel_k, out_idx,
                                 cum_expert, tt)[0]


def _or_specs(t_tot, k, h, rtcap, tt):
    full1 = lambda n: pl.BlockSpec((n,), lambda i: (0,))
    return dict(
        yflat=pl.BlockSpec((rtcap + 1, h), lambda i: (0, 0)),
        weights=pl.BlockSpec((tt, k), lambda i: (i, 0)),
        sel_k=full1(rtcap + 1),
        out_idx=full1(rtcap + 1),
        cum_expert=full1(t_tot + 1),
        out=pl.BlockSpec((tt, h), lambda i: (i, 0)),
    )


def _output_reduction_fwd(yflat, weights, sel_k, out_idx, cum_expert, tt):
    rtcap = yflat.shape[0] - 1
    h = yflat.shape[1]
    t_tot, k = weights.shape
    s = _or_specs(t_tot, k, h, rtcap, tt)
    out = pl.pallas_call(
        functools.partial(_output_reduction_fwd_kernel, k=k, tt=tt,
                          rtcap=rtcap),
        grid=(t_tot // tt,),
        in_specs=[s["yflat"], s["weights"], s["sel_k"], s["out_idx"],
                  s["cum_expert"]],
        out_specs=s["out"],
        out_shape=jax.ShapeDtypeStruct((t_tot, h), yflat.dtype),
        interpret=_INTERPRET,
    )(yflat, weights, sel_k, out_idx, cum_expert)
    return out, (yflat, weights, sel_k, out_idx, cum_expert)


def _output_reduction_bwd(tt, res, dout):
    yflat, weights, sel_k, out_idx, cum_expert = res
    rtcap = yflat.shape[0] - 1
    h = yflat.shape[1]
    t_tot, k = weights.shape
    s = _or_specs(t_tot, k, h, rtcap, tt)
    dy, dw = pl.pallas_call(
        functools.partial(_output_reduction_bwd_kernel, k=k, tt=tt,
                          rtcap=rtcap),
        grid=(t_tot // tt,),
        in_specs=[s["out"], s["yflat"], s["weights"], s["sel_k"],
                  s["out_idx"], s["cum_expert"]],
        out_specs=[s["yflat"], s["weights"]],
        out_shape=[
            jax.ShapeDtypeStruct((rtcap + 1, h), yflat.dtype),
            jax.ShapeDtypeStruct((t_tot, k), weights.dtype),
        ],
        interpret=_INTERPRET,
    )(dout, yflat, weights, sel_k, out_idx, cum_expert)
    return dy, dw, None, None, None


output_reduction.defvjp(_output_reduction_fwd, _output_reduction_bwd)


# ===========================================================================
# Assembled FastSparseMoE partial block (stages 2-5 for one EP rank)
# ===========================================================================

def fast_sparse_moe_partial(x_all, weights_all, indices_all,
                            gate_w, up_w, down_w, n_start,
                            tbs=DEFAULT_TBS, tile=DEFAULT_TILE):
    """Partial MoE output of one EP rank (Algorithm 1 stages 2-5).

    x_all [T,H], weights_all [T,K], indices_all [T,K] — the post-Stage-1
    (allgathered) tensors. gate_w/up_w [NR,H,I], down_w [NR,I,H] — merged
    local expert weights. Returns partial output [T,H] (float32 path),
    to be reduce-scattered by the coordinator.
    """
    t_tot, h = x_all.shape
    k = weights_all.shape[1]
    nr = gate_w.shape[0]
    i_dim = gate_w.shape[2]
    rtcap = t_tot * k
    rtpad = rtcap + nr * tile

    # integer routing plumbing is non-differentiable; sever any tangent
    # tracers so jax never tries to jvp through the stage-2/3 pallas calls
    meta = routing_metadata(jax.lax.stop_gradient(indices_all),
                            n_start, nr, tbs)
    cum = meta["cum_token_counts"]                      # [NR+1]
    counts = cum[1:] - cum[:-1]                         # [NR]
    input_idx = meta["input_indices"]                   # [RTCAP+1]

    # ---- tile-aligned padded layout (megablocks-style; DESIGN.md §7) ----
    pad_counts = ((counts + tile - 1) // tile) * tile
    pad_cum = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(pad_counts)])             # [NR+1]
    p = jnp.arange(rtpad, dtype=jnp.int32)
    e_of_p = jnp.searchsorted(pad_cum[1:], p, side="right").astype(jnp.int32)
    e_of_p = jnp.clip(e_of_p, 0, nr - 1)
    j_of_p = p - pad_cum[e_of_p]
    valid_p = (j_of_p < counts[e_of_p]) & (p < pad_cum[nr])
    flat_of_p = jnp.where(valid_p, cum[e_of_p] + j_of_p, rtcap)

    # token ids feeding each padded row (invalid -> zero row T)
    tok_of_p = jnp.where(valid_p,
                         jnp.clip(input_idx[flat_of_p], 0, t_tot), t_tot)
    x_pad_src = jnp.concatenate(
        [x_all, jnp.zeros((1, h), x_all.dtype)], axis=0)
    xpad = x_pad_src[tok_of_p]                          # [RTPAD, H]

    # per-tile expert weights (the VMEM-resident weight block per tile)
    n_tiles = rtpad // tile
    tile_expert = e_of_p[jnp.arange(n_tiles) * tile]
    gate_t = gate_w[tile_expert]
    up_t = up_w[tile_expert]
    down_t = down_w[tile_expert]

    ypad = grouped_mlp(xpad, gate_t, up_t, down_t, tile)  # [RTPAD, H]

    # padded -> flat (exact RT positions used by the stage-5 kernels)
    f = jnp.arange(rtcap, dtype=jnp.int32)
    e_of_f = jnp.searchsorted(cum[1:], f, side="right").astype(jnp.int32)
    e_of_f = jnp.clip(e_of_f, 0, nr - 1)
    pad_of_f = pad_cum[e_of_f] + (f - cum[e_of_f])
    valid_f = f < cum[nr]
    yflat = jnp.where(valid_f[:, None],
                      ypad[jnp.clip(pad_of_f, 0, rtpad - 1)], 0.0)
    yflat = jnp.concatenate([yflat, jnp.zeros((1, h), yflat.dtype)], axis=0)

    out = output_reduction(
        yflat, weights_all, meta["selected_expert_indices"],
        meta["output_indices"], meta["cum_expert_counts"],
        min(DEFAULT_TBS, t_tot))
    return out
