//! Aurora analytic performance model — regenerates the paper's scaling
//! and speedup *shapes* at scales this testbed cannot run (Fig 4b,
//! Table 3 projections). See DESIGN.md §1 for the substitution argument.
//!
//! Machine constants come from the Aurora architecture paper ([1] in the
//! paper): 12 PVC tiles/node, ~22.6 TFLOP/s bf16 achievable per tile,
//! 2×Slingshot-11 NICs/node (~25 GB/s each), dragonfly topology. The
//! collective model is hierarchical (intra-node fast, inter-node
//! ring/tree with α-β costs).

use crate::config::models::MulaSpec;
use crate::coordinator::pipeline::{bubble_fraction, Schedule};
use crate::util::prng::Prng;

#[derive(Clone, Copy, Debug)]
pub struct Aurora {
    pub tiles_per_node: usize,
    /// achievable bf16 FLOP/s per tile (not peak)
    pub tile_flops: f64,
    /// inter-node bandwidth per node (2 NICs)
    pub node_bw: f64,
    /// intra-node (Xe-Link) bandwidth per tile pair
    pub xelink_bw: f64,
    /// inter-node collective latency per hop
    pub alpha: f64,
    /// achievable fraction of peak on expert GEMMs (small-K penalty)
    pub gemm_eff: f64,
}

impl Default for Aurora {
    fn default() -> Self {
        Aurora {
            tiles_per_node: 12,
            tile_flops: 22.6e12,
            node_bw: 50e9,
            xelink_bw: 30e9,
            alpha: 15e-6,
            gemm_eff: 0.45,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ParallelPlan {
    pub dp: usize,
    pub ep: usize,
    pub pp: usize,
    pub micro_batches: usize,
    pub schedule: Schedule,
    /// tokens per tile per step (sequence × local batch)
    pub tokens_per_tile: usize,
    pub fur: bool,
    /// bytes per element on gradient/activation wires — follows the plan
    /// dtype: 2.0 for bf16 (the paper's production precision, and the
    /// default every projection in the paper assumes), 4.0 for f32
    pub wire_bytes: f64,
    /// tiles per node — the intra/inter split the hierarchical
    /// collectives are built around (mirrors
    /// [`crate::comm::Topology::node_size`]; Aurora packs 12)
    pub node_size: usize,
}

impl ParallelPlan {
    /// Wire width for a plan dtype string (`"f32"` / `"bf16"`).
    pub fn wire_bytes_for(dtype: &str) -> f64 {
        if dtype == "f32" {
            4.0
        } else {
            2.0
        }
    }
}

/// Expert-load imbalance factor: max/mean load over experts when routing
/// T·K selections over E experts. FUR forces exactly 1.0; otherwise we
/// sample a multinomial with a mild hot-expert skew (softmax routers are
/// never perfectly balanced even with the aux loss).
pub fn imbalance_factor(tokens_k: usize, experts: usize, fur: bool, seed: u64) -> f64 {
    if fur || experts <= 1 {
        return 1.0;
    }
    let mut rng = Prng::new(seed);
    // per-expert probabilities with ±20% systematic skew
    let probs: Vec<f64> = (0..experts)
        .map(|e| 1.0 + 0.2 * ((e as f64 * 2.39996).sin()))
        .collect();
    let total: f64 = probs.iter().sum();
    let mut counts = vec![0u64; experts];
    // sample in expectation + binomial noise (cheap approximation of the
    // multinomial for large T)
    for (e, p) in probs.iter().enumerate() {
        let mean = tokens_k as f64 * p / total;
        let noise = rng.normal() * mean.sqrt();
        counts[e] = (mean + noise).max(0.0) as u64;
    }
    let max = *counts.iter().max().unwrap() as f64;
    let mean = counts.iter().sum::<u64>() as f64 / experts as f64;
    (max / mean).max(1.0)
}

/// Modeled time for one training step (seconds) with its breakdown.
#[derive(Clone, Debug, Default)]
pub struct StepModel {
    pub compute: f64,
    pub dp_comm: f64,
    pub ep_comm: f64,
    pub pp_bubble: f64,
    pub optimizer: f64,
}

impl StepModel {
    pub fn total(&self) -> f64 {
        self.compute + self.dp_comm + self.ep_comm + self.pp_bubble + self.optimizer
    }

    /// Predicted speedup from overlapping the DP gradient collectives
    /// with compute (the `--overlap` pipelined optimizer, paper §3.2):
    /// the hidable comm is bounded by the compute it hides behind.
    pub fn overlap_speedup(&self) -> f64 {
        let hidden = self.dp_comm.min(self.compute);
        self.total() / (self.total() - hidden).max(f64::MIN_POSITIVE)
    }
}

/// Predicted inter-node traffic ratio of hierarchical vs flat sum
/// collectives at `node_size` tiles per node: only the per-node leader
/// exchanges full frames inter-node, so the inter bytes shrink to
/// `1/node_size` of the flat all-pairs rendezvous. (Gather-type ops
/// reduce less — leaders re-read the full concat — so a measured
/// training mix lands between this and 1; `optimus predict` reports
/// the gap as model error.)
pub fn hier_inter_traffic_ratio(node_size: usize) -> f64 {
    if node_size <= 1 {
        1.0
    } else {
        1.0 / node_size as f64
    }
}

pub fn step_time(m: &MulaSpec, hw: &Aurora, plan: &ParallelPlan, epso: bool) -> StepModel {
    let tiles = plan.dp * plan.ep * plan.pp;
    // the plan's node packing — not the machine constant — decides how
    // many nodes the placement spans (a half-packed job spans twice the
    // nodes of a dense one, and its inter-node terms price accordingly)
    let node_size = plan.node_size.max(1);
    let nodes = (tiles + node_size - 1) / node_size;
    let tokens_local = plan.tokens_per_tile as f64;

    // ---- compute: fwd+bwd FLOPs on the tile's share of the model ----
    let flops_per_token = m.train_flops_per_token() / plan.pp as f64;
    let imb = imbalance_factor(
        (plan.tokens_per_tile * m.top_k.max(1)) as usize,
        m.n_experts.max(1),
        plan.fur,
        tiles as u64,
    );
    // expert share of compute rides the imbalance factor
    let e_frac = m.expert_param_fraction();
    let compute = tokens_local * flops_per_token
        * (1.0 - e_frac + e_frac * imb)
        / (hw.tile_flops * hw.gemm_eff);

    // ---- DP gradient reduce-scatter + param allgather ----
    // gradients at the plan's wire width over the per-stage parameters
    let bytes = plan.wire_bytes * (m.param_count() / plan.pp) as f64;
    // DP spans node groups (EP fills the node, PP spans nodes), so the
    // gradient ring runs over the DP degree itself; its bandwidth term
    // saturates at 2V/BW — this saturation is what produces the paper's
    // ~90% plateau from 1.5k to 12k tiles
    let ring = |n: f64, v: f64| {
        if n <= 1.0 {
            0.0
        } else {
            2.0 * (n - 1.0) / n * v / hw.node_bw + 2.0 * (n - 1.0).log2().max(0.0) * hw.alpha * 40.0
        }
    };
    let dp_comm = ring(plan.dp as f64, bytes) // RS + AG (2V(n-1)/n total)
        + bytes / hw.xelink_bw; // intra-node staging

    // ---- EP Stage-1 exchange (allgather within the node) ----
    let h = m.hidden as f64;
    // x + grads, each at the plan's wire width
    let ep_bytes = tokens_local * plan.ep as f64 * h * plan.wire_bytes * 2.0;
    let ep_comm = if plan.ep > 1 { ep_bytes / hw.xelink_bw } else { 0.0 };

    // ---- PP bubble ----
    let bubble = bubble_fraction(plan.schedule, plan.pp, plan.micro_batches);
    let pp_bubble = compute * bubble / (1.0 - bubble);

    // ---- optimizer: memory-bound AdamW over the rank's shard ----
    let (e_params, ne_params) = {
        let e = (m.param_count() as f64) * e_frac;
        (e, m.param_count() as f64 - e)
    };
    let shard = if epso {
        ne_params / (plan.dp * plan.ep) as f64 + e_params / plan.ep as f64 / plan.dp as f64
    } else {
        // SO: NE states replicated EP times
        ne_params / plan.dp as f64 + e_params / plan.ep as f64 / plan.dp as f64
    } / plan.pp as f64;
    // 16 bytes/param state traffic at ~0.5 TB/s effective HBM
    let optimizer = shard * 16.0 / 0.5e12 + if nodes > 1 { ring(nodes as f64, 0.0) } else { 0.0 };

    StepModel { compute, dp_comm, ep_comm, pp_bubble, optimizer }
}

/// Weak-scaling efficiency vs the 384-tile baseline (Fig 4b): global batch
/// grows with tiles, per-tile work constant, so efficiency =
/// t_step(384) / t_step(tiles).
pub fn scaling_efficiency(
    m: &MulaSpec,
    hw: &Aurora,
    base_tiles: usize,
    tiles: usize,
    fur: bool,
) -> f64 {
    let plan = |t: usize| ParallelPlan {
        dp: t / 8 / 12 * 12, // PP=8, EP=12 within node (paper's 220B plan)
        ep: 12,
        pp: 8,
        micro_batches: 16,
        schedule: Schedule::OneFOneB,
        tokens_per_tile: 4096,
        fur,
        wire_bytes: 2.0,
        node_size: 12,
    };
    let fix = |t: usize| {
        let mut p = plan(t);
        // dp degree = tiles / (ep*pp)
        p.dp = (t / (p.ep * p.pp)).max(1);
        p
    };
    let t0 = step_time(m, hw, &fix(base_tiles), true).total();
    let t1 = step_time(m, hw, &fix(tiles), true).total();
    t0 / t1
}

/// Table 3 projection: EPSO optimizer-component speedup = SO shard size /
/// EPSO shard size (memory-bound update).
pub fn epso_optimizer_speedup(m: &MulaSpec, ep: usize) -> f64 {
    let e = m.expert_param_fraction();
    let ne = 1.0 - e;
    let e_loc = e / ep as f64;
    (ne + e_loc) / (ne / ep as f64 + e_loc)
}

/// Table 3 projection: FSMOE fwd+bwd speedup — naive computes every
/// expert on every token (N/K times the routed FLOPs) plus dispatch
/// overhead; FSMOE computes routed tokens with tile padding.
pub fn fsmoe_fwdbwd_speedup(m: &MulaSpec, ep: usize, tile_rows: usize) -> f64 {
    if !m.is_moe() {
        return 1.0;
    }
    let t = 4096.0; // tokens in flight per rank
    let k = m.top_k as f64;
    let n_local = (m.n_experts / ep) as f64;
    let routed = t * k; // routed token-expert pairs
    // HF baseline: same routed FLOPs but many small per-expert GEMMs at
    // ~half efficiency plus a fixed dispatch/indexing overhead per expert
    let naive = routed * 2.0 + n_local * 0.3 * t;
    let pad = n_local * tile_rows as f64; // FSMOE tile-padding overhead
    let e_frac = m.expert_param_fraction();
    // non-expert (attention/router) time shared by both paths
    let rest = (1.0 - e_frac) / e_frac * routed;
    (naive + rest) / (routed + pad + rest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::*;

    #[test]
    fn epso_speedup_matches_table3() {
        // paper Table 3 optimizer column: 1.36 / 1.23 / 1.07
        let cases = [(&MULA_20B, 1.36), (&MULA_100B, 1.23), (&MULA_220B, 1.07)];
        for (m, want) in cases {
            let got = epso_optimizer_speedup(m, 12);
            assert!(
                (got - want).abs() < 0.08,
                "{}: modeled {got:.3} vs paper {want}",
                m.name
            );
        }
    }

    #[test]
    fn fsmoe_speedup_is_in_paper_band() {
        // paper Table 3 F+B column: 1.33-2.83x; shape: fewer experts per
        // rank and fewer layers -> bigger win for mula-7b (EP=1)
        let s7 = fsmoe_fwdbwd_speedup(&MULA_7B, 1, 64);
        let s20 = fsmoe_fwdbwd_speedup(&MULA_20B, 12, 64);
        assert!(s7 > 1.5 && s7 < 4.0, "{s7}");
        assert!(s20 > 1.1 && s20 < s7, "{s20} vs {s7}");
    }

    #[test]
    fn scaling_efficiency_shape_matches_fig4b() {
        let hw = Aurora::default();
        let m = &MULA_220B;
        let e768 = scaling_efficiency(m, &hw, 384, 768, false);
        let e1536 = scaling_efficiency(m, &hw, 384, 1536, false);
        let e12288 = scaling_efficiency(m, &hw, 384, 12288, false);
        // paper: ~97% at 768, ~90% plateau from 1536 to 12288
        assert!(e768 > 0.93 && e768 <= 1.0, "{e768}");
        assert!(e1536 > 0.82 && e1536 < 0.97, "{e1536}");
        assert!(e12288 > 0.80 && e12288 < 0.95, "{e12288}");
        // plateau: the drop from 1536 to 12288 is small
        assert!((e1536 - e12288).abs() < 0.06, "{e1536} vs {e12288}");
    }

    #[test]
    fn fur_removes_imbalance() {
        let with = imbalance_factor(1 << 16, 240, false, 1);
        let without = imbalance_factor(1 << 16, 240, true, 1);
        assert_eq!(without, 1.0);
        assert!(with > 1.05, "{with}");
        // FUR and non-FUR show similar *scaling* dynamics (paper Fig 4b):
        let hw = Aurora::default();
        let ef = scaling_efficiency(&MULA_220B, &hw, 384, 12288, true);
        let en = scaling_efficiency(&MULA_220B, &hw, 384, 12288, false);
        assert!((ef - en).abs() < 0.05, "FUR {ef} vs regular {en}");
    }

    #[test]
    fn step_breakdown_is_positive_and_dominated_by_compute() {
        let hw = Aurora::default();
        let plan = ParallelPlan {
            dp: 32,
            ep: 12,
            pp: 8,
            micro_batches: 16,
            schedule: Schedule::OneFOneB,
            tokens_per_tile: 4096,
            fur: false,
            wire_bytes: 2.0,
            node_size: 12,
        };
        let s = step_time(&MULA_220B, &hw, &plan, true);
        assert!(s.compute > 0.0 && s.total() > s.compute);
        assert!(s.compute / s.total() > 0.35, "{s:?}");
    }

    #[test]
    fn node_size_drives_the_internode_split() {
        let hw = Aurora::default();
        let mk = |node_size| ParallelPlan {
            dp: 32,
            ep: 12,
            pp: 8,
            micro_batches: 16,
            schedule: Schedule::OneFOneB,
            tokens_per_tile: 4096,
            fur: false,
            wire_bytes: 2.0,
            node_size,
        };
        // half-packed nodes span twice as many, so the optimizer's
        // inter-node latency term grows; compute never moves
        let dense = step_time(&MULA_220B, &hw, &mk(12), true);
        let sparse = step_time(&MULA_220B, &hw, &mk(6), true);
        assert_eq!(dense.compute, sparse.compute);
        assert!(sparse.optimizer > dense.optimizer, "{} vs {}", sparse.optimizer, dense.optimizer);
        // the hierarchical traffic prediction `optimus predict` checks
        assert_eq!(hier_inter_traffic_ratio(1), 1.0);
        assert!((hier_inter_traffic_ratio(12) - 1.0 / 12.0).abs() < 1e-12);
        // overlap can only help, and only up to hiding all dp comm
        let s = dense;
        assert!(s.overlap_speedup() >= 1.0);
        assert!(s.overlap_speedup() <= s.total() / (s.total() - s.dp_comm) + 1e-9);
    }

    #[test]
    fn f32_wires_cost_more_comm_than_bf16() {
        let hw = Aurora::default();
        let mk = |wire_bytes: f64| ParallelPlan {
            dp: 32,
            ep: 12,
            pp: 8,
            micro_batches: 16,
            schedule: Schedule::OneFOneB,
            tokens_per_tile: 4096,
            fur: false,
            wire_bytes,
            node_size: 12,
        };
        let bf16 = step_time(&MULA_220B, &hw, &mk(2.0), true);
        let f32w = step_time(&MULA_220B, &hw, &mk(4.0), true);
        // compute and bubble are dtype-independent in the model; both
        // wire terms must grow with the wider dtype
        assert_eq!(bf16.compute, f32w.compute);
        assert!(f32w.dp_comm > bf16.dp_comm, "{} vs {}", f32w.dp_comm, bf16.dp_comm);
        assert!(f32w.ep_comm > bf16.ep_comm, "{} vs {}", f32w.ep_comm, bf16.ep_comm);
        assert_eq!(ParallelPlan::wire_bytes_for("f32"), 4.0);
        assert_eq!(ParallelPlan::wire_bytes_for("bf16"), 2.0);
    }
}
