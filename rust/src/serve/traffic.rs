//! Synthetic open-loop traffic generator for the serving engine.
//!
//! One named producer thread replays a seeded workload: Poisson arrivals
//! (exponential inter-arrival gaps at `rate_rps`; `rate <= 0` disables
//! pacing and offers load as fast as the lanes drain) with per-request
//! prompt/generation lengths drawn uniformly from configured ranges.
//! Request *content* is derived from a per-id PRNG fork, so the workload
//! is a pure function of the seed — identical across reruns, lane
//! counts, and batching modes regardless of wall-clock arrival jitter.
//! That is what lets the tests assert same-seed → same completion set
//! and lets the perf gate compare continuous vs static batching on an
//! identical request stream.
//!
//! Requests fan out round-robin by id over per-lane **bounded** queues
//! (`sync_channel`, in the prefetcher's mold): when a lane's queue fills
//! — slots busy, KV pool exhausted — the producer blocks in `send`, which
//! is exactly where serving backpressure meets the open-loop source.
//! Dropping the senders after the last request closes every queue, so
//! lanes observe end-of-traffic as a disconnect and drain to completion.

use crate::util::prng::Prng;
use crate::Result;
use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Workload shape for one serving run.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    pub seed: u64,
    /// total requests to offer (bounded mode — the only mode; a run is
    /// complete when every one of these has a completion)
    pub requests: usize,
    /// Poisson arrival rate in requests/sec; `<= 0` offers load unpaced
    pub rate_rps: f64,
    /// inclusive prompt-length range in tokens
    pub prompt_len: (usize, usize),
    /// inclusive generation-length range in tokens
    pub gen_len: (usize, usize),
    /// per-lane arrival-queue depth (the backpressure bound)
    pub queue_depth: usize,
}

impl Default for TrafficConfig {
    fn default() -> TrafficConfig {
        TrafficConfig {
            seed: 0,
            requests: 16,
            rate_rps: 0.0,
            prompt_len: (4, 8),
            gen_len: (4, 12),
            queue_depth: 4,
        }
    }
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// generation budget (the request completes after exactly this many
    /// decoded tokens)
    pub max_new: usize,
    /// creation time at the source — TTFT measures from here, so queue
    /// wait under backpressure counts against the server
    pub arrival: Instant,
}

/// Deterministic request content: an independent PRNG stream per id, so
/// content never depends on arrival timing or lane count.
pub(crate) fn request_content(cfg: &TrafficConfig, id: u64, vocab: usize) -> (Vec<i32>, usize) {
    let mut rng = Prng::new(cfg.seed).fork(id.wrapping_add(1));
    let plen = rng.range(cfg.prompt_len.0, cfg.prompt_len.1 + 1);
    let glen = rng.range(cfg.gen_len.0, cfg.gen_len.1 + 1);
    let prompt = (0..plen).map(|_| rng.below(vocab) as i32).collect();
    (prompt, glen)
}

/// Spawn the producer; returns one bounded receiver per lane plus the
/// producer's join handle.
pub(crate) fn spawn(
    cfg: TrafficConfig,
    lanes: usize,
    vocab: usize,
) -> Result<(Vec<Receiver<Request>>, JoinHandle<()>)> {
    let (txs, rxs): (Vec<_>, Vec<_>) =
        (0..lanes).map(|_| sync_channel::<Request>(cfg.queue_depth)).unzip();
    let handle = std::thread::Builder::new()
        .name("serve-traffic".to_string())
        .spawn(move || {
            // pacing stream is separate from content streams: jitter in
            // arrival times never perturbs what gets asked
            let mut clock = Prng::new(cfg.seed).fork(0x0717);
            for id in 0..cfg.requests as u64 {
                if cfg.rate_rps > 0.0 {
                    let gap = -(1.0 - clock.next_f64()).ln() / cfg.rate_rps;
                    std::thread::sleep(Duration::from_secs_f64(gap.min(1.0)));
                }
                let (prompt, max_new) = request_content(&cfg, id, vocab);
                let lane = (id as usize) % lanes;
                let req = Request { id, prompt, max_new, arrival: Instant::now() };
                // bounded queue: a full lane blocks the producer here —
                // open-loop arrivals feel slot/KV backpressure. A closed
                // lane (rank error) ends the offered load early.
                if txs[lane].send(req).is_err() {
                    return;
                }
            }
            // senders drop here → every lane sees a disconnect
        })?;
    Ok((rxs, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_is_a_pure_function_of_seed_and_id() {
        let cfg = TrafficConfig { seed: 9, ..TrafficConfig::default() };
        for id in 0..20 {
            let (p1, g1) = request_content(&cfg, id, 256);
            let (p2, g2) = request_content(&cfg, id, 256);
            assert_eq!(p1, p2);
            assert_eq!(g1, g2);
            assert!(p1.len() >= 4 && p1.len() <= 8);
            assert!(g1 >= 4 && g1 <= 12);
            assert!(p1.iter().all(|&t| (0..256).contains(&t)));
        }
        let other = TrafficConfig { seed: 10, ..TrafficConfig::default() };
        let streams_differ = (0..20).any(|id| {
            request_content(&cfg, id, 256).0 != request_content(&other, id, 256).0
        });
        assert!(streams_differ);
    }

    #[test]
    fn producer_round_robins_and_closes_lanes() {
        let cfg = TrafficConfig { requests: 10, queue_depth: 10, ..TrafficConfig::default() };
        let (rxs, handle) = spawn(cfg.clone(), 3, 256).unwrap();
        let mut per_lane = Vec::new();
        for (lane, rx) in rxs.iter().enumerate() {
            let ids: Vec<u64> = rx.iter().map(|r| r.id).collect(); // drains until disconnect
            assert!(ids.iter().all(|id| (*id as usize) % 3 == lane));
            per_lane.push(ids.len());
        }
        assert_eq!(per_lane.iter().sum::<usize>(), 10);
        handle.join().unwrap();
    }
}
